//! Bank state machine: open-row policy, row-hit tracking and the cycle
//! layout of MAC sweeps and KV write-backs.
//!
//! A bank is driven with either
//! * *segment lists* — explicit `(row, elems)` spans, used for KV-cache
//!   reads whose shape depends on the runtime token position, or
//! * *blocks* — `base_row + n` consecutive fully-mapped rows, the layout
//!   the weight mapper produces (Fig. 6). Blocks are laid out in O(1)
//!   cycles-math instead of materializing millions of segments, which is
//!   what makes a 1024-token GPT2-XL run tractable.
//!
//! **Matrix-matrix passes** (chunked prefill): the MAC unit is
//! weight-stationary — a DRAM row, once activated, can be streamed
//! against any number of input vectors staged in the channel's global
//! buffer. `mac_block` / `mac_pattern` therefore take a `passes` count:
//! each row pays its ACT/PRE *once* and then `passes` MAC streams of
//! `fill + chunks * tCCD` cycles, so the per-vector row-switch overhead
//! amortizes as 1/passes. `passes = 1` is the classic vector-matrix
//! cycle layout, bit-identical to the original math; `passes = T` is
//! exactly a `mac_sweep` in which each row's segment appears `T`
//! consecutive times (every repetition after the first is an open-row
//! hit) — pinned by `prop_block_passes_matches_repeated_sweep` /
//! `prop_pattern_passes_matches_repeated_sweep`.
//!
//! Row-hit statistics are counted at *column-command* granularity (every
//! `tCCD`-spaced MAC/write chunk is one access), which is the semantics
//! under which the paper reports ~98% hit rates (Fig. 11a): a fully
//! mapped 1024-element row costs 1 ACT then 64 hit accesses.

use super::command::CommandCounts;
use super::timing::TimingCycles;

/// A contiguous span of `elems` bf16 values inside DRAM row `row`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowSegment {
    pub row: u32,
    pub elems: u32,
}

/// A run of consecutive, fully-mapped rows plus an optional tail row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowBlock {
    pub base_row: u32,
    pub full_rows: u32,
    /// Elements in the final partial row (0 = none).
    pub tail_elems: u32,
}

impl RowBlock {
    pub fn total_rows(&self) -> u32 {
        self.full_rows + (self.tail_elems > 0) as u32
    }

    pub fn total_elems(&self, row_elems: u32) -> u64 {
        self.full_rows as u64 * row_elems as u64 + self.tail_elems as u64
    }
}

/// Row-buffer statistics at column-access granularity (Fig. 11a).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BankStats {
    pub row_hits: u64,
    pub row_misses: u64,
}

impl BankStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses;
        if total == 0 {
            return 1.0;
        }
        self.row_hits as f64 / total as f64
    }

    pub fn merge(&mut self, o: &BankStats) {
        self.row_hits += o.row_hits;
        self.row_misses += o.row_misses;
    }
}

/// One DRAM bank with its MAC unit.
#[derive(Clone, Debug)]
pub struct Bank {
    /// Open-row policy: the currently open row, if any.
    open_row: Option<u32>,
    /// Cycle at which the open row was activated (tRAS enforcement).
    opened_at: u64,
    /// Cycle at which the bank becomes idle.
    busy_until: u64,
    pub stats: BankStats,
    pub cmds: CommandCounts,
}

impl Default for Bank {
    fn default() -> Self {
        Self::new()
    }
}

impl Bank {
    pub fn new() -> Self {
        Self {
            open_row: None,
            opened_at: 0,
            busy_until: 0,
            stats: BankStats::default(),
            cmds: CommandCounts::default(),
        }
    }

    pub fn busy_until(&self) -> u64 {
        self.busy_until
    }

    pub fn open_row(&self) -> Option<u32> {
        self.open_row
    }

    /// Open `row` at time `now`, closing any conflicting open row first.
    /// Returns the cycle at which data in the row buffer is accessible.
    /// The *first* column access of the caller is the hit/miss event.
    fn open(&mut self, now: u64, row: u32, t: &TimingCycles) -> (u64, bool) {
        match self.open_row {
            Some(r) if r == row => (now, true),
            Some(_) => {
                // Respect tRAS before precharging the old row.
                let pre_at = now.max(self.opened_at + t.tras);
                let act_at = pre_at + t.trp;
                self.cmds.pre += 1;
                self.cmds.act += 1;
                self.open_row = Some(row);
                self.opened_at = act_at;
                (act_at + t.trcd, false)
            }
            None => {
                self.cmds.act += 1;
                self.open_row = Some(row);
                self.opened_at = now;
                (now + t.trcd, false)
            }
        }
    }

    /// Execute a MAC sweep over explicit `segments` starting no earlier
    /// than `start`. Each segment is consumed at `lanes` values per
    /// `tCCD`; the adder-tree pipeline adds `pipeline_fill` per segment.
    pub fn mac_sweep(
        &mut self,
        start: u64,
        segments: &[RowSegment],
        t: &TimingCycles,
        lanes: u64,
        pipeline_fill: u64,
    ) -> u64 {
        let mut now = start.max(self.busy_until);
        let begin = now;
        for seg in segments {
            let (ready, hit) = self.open(now, seg.row, t);
            now = ready;
            let chunks = crate::util::ceil_div(seg.elems as u64, lanes);
            if hit {
                self.stats.row_hits += chunks;
            } else {
                self.stats.row_misses += 1;
                self.stats.row_hits += chunks - 1;
            }
            self.cmds.mac_read_cycles += chunks * t.tccd;
            now += pipeline_fill + chunks * t.tccd;
        }
        self.cmds.busy_cycles += now - begin;
        self.busy_until = now;
        now
    }

    /// MAC over a weight block: `full_rows` consecutive fully-mapped rows
    /// from `base_row` plus an optional tail — O(1) regardless of size.
    /// `passes` input vectors stream through each row while it is open
    /// (matrix-matrix mode, see the module docs); `passes = 1` is the
    /// classic vector-matrix layout.
    #[allow(clippy::too_many_arguments)]
    pub fn mac_block(
        &mut self,
        start: u64,
        block: &RowBlock,
        row_elems: u32,
        t: &TimingCycles,
        lanes: u64,
        pipeline_fill: u64,
        passes: u64,
    ) -> u64 {
        let rows = block.total_rows();
        if rows == 0 || passes == 0 {
            return start.max(self.busy_until);
        }
        let mut now = start.max(self.busy_until);
        let begin = now;
        let chunks_full = crate::util::ceil_div(row_elems as u64, lanes);
        // One ACT covers all `passes` streams of a row.
        let row_cost = passes * (pipeline_fill + chunks_full * t.tccd);

        // First row: hit if it happens to be open, else ACT (+PRE).
        let (ready, hit) = self.open(now, block.base_row, t);
        now = ready;
        let first_chunks = if block.full_rows > 0 {
            chunks_full
        } else {
            crate::util::ceil_div(block.tail_elems as u64, lanes)
        };
        if hit {
            self.stats.row_hits += passes * first_chunks;
        } else {
            self.stats.row_misses += 1;
            self.stats.row_hits += passes * first_chunks - 1;
        }
        now += passes * (pipeline_fill + first_chunks * t.tccd);
        self.cmds.mac_read_cycles += passes * first_chunks * t.tccd;

        // Remaining full rows: every one is a conflict miss. The per-row
        // occupancy (fill + chunks) exceeds tRAS for 1 KB rows at 16
        // lanes, so PRE issues immediately: cost = tRP + tRCD + row_cost.
        // (For exotic configs where the MAC drains a row faster than
        // tRAS, add the residency shortfall.)
        let remaining_full = block.full_rows.saturating_sub(1) as u64;
        let switch = t.trp + t.trcd;
        let residency_gap = t.tras.saturating_sub(row_cost);
        if remaining_full > 0 {
            now += remaining_full * (switch + row_cost + residency_gap);
            self.cmds.pre += remaining_full;
            self.cmds.act += remaining_full;
            self.cmds.mac_read_cycles += remaining_full * passes * chunks_full * t.tccd;
            self.stats.row_misses += remaining_full;
            self.stats.row_hits += remaining_full * (passes * chunks_full - 1);
        }

        // Tail row (only when there were full rows before it).
        if block.tail_elems > 0 && block.full_rows > 0 {
            let chunks_tail = crate::util::ceil_div(block.tail_elems as u64, lanes);
            now += t.tras.saturating_sub(row_cost); // residency of prev row
            now += switch + passes * (pipeline_fill + chunks_tail * t.tccd);
            self.cmds.pre += 1;
            self.cmds.act += 1;
            self.cmds.mac_read_cycles += passes * chunks_tail * t.tccd;
            self.stats.row_misses += 1;
            self.stats.row_hits += passes * chunks_tail - 1;
        }

        // Track the open row + activation time of the final row.
        let last_row = block.base_row + rows - 1;
        self.open_row = Some(last_row);
        if rows > 1 {
            // Conservative: the final activation happened `row_cost` ago.
            self.opened_at = now.saturating_sub(row_cost);
        }
        self.cmds.busy_cycles += now - begin;
        self.busy_until = now;
        now
    }

    /// MAC over `reps` repetitions of a row-fill `pattern` starting at
    /// `base_row` — O(|pattern|) regardless of `reps`. This is the KV-
    /// cache read fast path: a unit's K region is `owned_tokens` copies
    /// of the per-token row fill (e.g. d=1536 -> [1024, 512]), its V
    /// region `owned_cols` copies of the per-column fill. All rows are
    /// distinct, so every row after the first is a conflict miss; cycle
    /// math mirrors `mac_sweep` exactly (`prop_pattern_matches_sweep`).
    /// `passes` input vectors stream through each row while it is open
    /// (matrix-matrix mode — one ACT, `passes` MAC streams per row);
    /// `passes = 1` is the classic vector-matrix layout.
    ///
    /// Derivation: in `mac_sweep`, rows 2..n each cost
    /// `gap(prev) + tRP + tRCD + passes * (fill + chunks(row))` where
    /// `gap(e) = max(0, tRAS - tRCD - passes * (fill + chunks(e)))` is
    /// the residency shortfall of the row being closed. Over a repeating
    /// pattern the two sums telescope to `reps * sum(cost+gap) -
    /// cost(first) - gap(last)`.
    #[allow(clippy::too_many_arguments)]
    pub fn mac_pattern(
        &mut self,
        start: u64,
        base_row: u32,
        reps: u32,
        pattern: &[u32],
        t: &TimingCycles,
        lanes: u64,
        pipeline_fill: u64,
        passes: u64,
    ) -> u64 {
        if reps == 0 || pattern.is_empty() || passes == 0 {
            return start.max(self.busy_until);
        }
        let mut now = start.max(self.busy_until);
        let begin = now;
        let switch = t.trp + t.trcd;
        let chunks = |e: u32| crate::util::ceil_div(e as u64, lanes);
        let stream = |e: u32| passes * (pipeline_fill + chunks(e) * t.tccd);
        let cost = |e: u32| switch + stream(e);
        let gap = |e: u32| t.tras.saturating_sub(t.trcd + stream(e));

        let k = pattern.len() as u64;
        let n_rows = reps as u64 * k;
        let sum_cost_gap: u64 = pattern.iter().map(|&e| cost(e) + gap(e)).sum();
        let sum_chunks: u64 = pattern.iter().map(|&e| passes * chunks(e)).sum();

        // First row: hit if already open, else ACT (+PRE on conflict).
        let first_chunks = passes * chunks(pattern[0]);
        let (ready, hit) = self.open(now, base_row, t);
        now = ready + stream(pattern[0]);
        if hit {
            self.stats.row_hits += first_chunks;
        } else {
            self.stats.row_misses += 1;
            self.stats.row_hits += first_chunks - 1;
        }
        self.cmds.mac_read_cycles += first_chunks * t.tccd;

        // Rows 2..n, closed form (see derivation above).
        if n_rows > 1 {
            let last = pattern[((n_rows - 1) % k) as usize];
            now += reps as u64 * sum_cost_gap - cost(pattern[0]) - gap(last);
            let remaining = n_rows - 1;
            let rem_chunks = reps as u64 * sum_chunks - first_chunks;
            self.cmds.pre += remaining;
            self.cmds.act += remaining;
            self.cmds.mac_read_cycles += rem_chunks * t.tccd;
            self.stats.row_misses += remaining;
            self.stats.row_hits += rem_chunks - remaining;
        }

        self.open_row = Some(base_row + n_rows as u32 - 1);
        let last = pattern[((n_rows - 1) % k) as usize];
        // Last row's ACT was tRCD + its full pass stream before `now`
        // (matches the opened_at a mac_sweep over the same rows would
        // leave).
        self.opened_at = now.saturating_sub(t.trcd + stream(last));
        self.cmds.busy_cycles += now - begin;
        self.busy_until = now;
        now
    }

    /// Cycle at which the first partial result of a sweep starting at
    /// `start` would be available for forwarding (drain pipelining).
    pub fn first_result_at(
        &self,
        start: u64,
        first_row: u32,
        t: &TimingCycles,
        pipeline_fill: u64,
    ) -> u64 {
        let now = start.max(self.busy_until);
        let open_penalty = match self.open_row {
            Some(r) if r == first_row => 0,
            Some(_) => t.trp + t.trcd,
            None => t.trcd,
        };
        now + open_penalty + pipeline_fill + t.tccd
    }

    /// Row-major write-back (Key vectors, Fig. 7a): one ACT, then
    /// consecutive column writes, one write recovery at the end.
    pub fn write_row_major(&mut self, start: u64, seg: RowSegment, t: &TimingCycles) -> u64 {
        let mut now = start.max(self.busy_until);
        let begin = now;
        let (ready, hit) = self.open(now, seg.row, t);
        now = ready;
        let writes = seg.elems as u64; // one bf16 pair per tCCD in practice;
                                       // modeled as elems/lanes-agnostic column writes
        let wr_chunks = crate::util::ceil_div(writes, 16);
        if hit {
            self.stats.row_hits += wr_chunks;
        } else {
            self.stats.row_misses += 1;
            self.stats.row_hits += wr_chunks.saturating_sub(1);
        }
        now += wr_chunks * t.tccd + t.twr;
        self.cmds.write_cycles += wr_chunks * t.tccd;
        self.cmds.write_recoveries += 1;
        self.cmds.busy_cycles += now - begin;
        self.busy_until = now;
        now
    }

    /// Column-major write-back (Value vectors, Fig. 7b): each element
    /// lands in a different row — ACT, single write, tWR, PRE per element.
    /// Data locality cannot be exploited (paper §IV.B). `row_stride` is
    /// the per-column row pitch (> 1 when a V column spans several rows,
    /// i.e. max_seq > row_elems).
    pub fn write_col_major(
        &mut self,
        start: u64,
        n_elems: u32,
        base_row: u32,
        row_stride: u32,
        t: &TimingCycles,
    ) -> u64 {
        if n_elems == 0 {
            return start.max(self.busy_until);
        }
        let mut now = start.max(self.busy_until);
        let begin = now;
        // First element through the generic open() (it may conflict with
        // whatever row is currently open).
        let (ready, hit) = self.open(now, base_row, t);
        now = ready;
        if !hit {
            self.stats.row_misses += 1;
        } else {
            self.stats.row_hits += 1;
        }
        now += t.tccd + t.twr;
        let pre_at = now.max(self.opened_at + t.tras);
        now = pre_at + t.trp;
        // Elements 2..n in closed form: each is ACT + tRCD + write +
        // tWR, a tRAS-residency wait if the row closed too fast, + tRP.
        let residency = t.trcd + t.tccd + t.twr;
        let per_elem = t.trcd + t.tccd + t.twr + t.tras.saturating_sub(residency) + t.trp;
        let rest = (n_elems - 1) as u64;
        now += rest * per_elem;
        self.cmds.act += rest;
        self.cmds.pre += rest + 1;
        self.stats.row_misses += rest;
        self.cmds.write_cycles += n_elems as u64 * t.tccd;
        self.cmds.write_recoveries += n_elems as u64;
        self.open_row = None;
        let _ = row_stride; // row ids don't affect cost (all distinct)
        self.cmds.busy_cycles += now - begin;
        self.busy_until = now;
        now
    }

    /// Inject a refresh stall (tRFC) at `now` — issued per channel.
    pub fn refresh(&mut self, now: u64, t: &TimingCycles) -> u64 {
        let start = now.max(self.busy_until);
        // Refresh closes all rows.
        self.open_row = None;
        self.cmds.refresh += 1;
        self.busy_until = start + t.trfc;
        self.cmds.busy_cycles += t.trfc;
        self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;
    use crate::util::prop::check;

    fn t() -> TimingCycles {
        TimingCycles::from_config(&HwConfig::paper_baseline())
    }

    #[test]
    fn segment_sweep_hits_open_row() {
        let mut b = Bank::new();
        let tm = t();
        let segs = [RowSegment { row: 3, elems: 1024 }, RowSegment { row: 3, elems: 512 }];
        let fin = b.mac_sweep(0, &segs, &tm, 16, 5);
        // ACT(12) + fill(5) + 64 chunks + fill(5) + 32 chunks
        assert_eq!(fin, 12 + 5 + 64 + 5 + 32);
        assert_eq!(b.cmds.act, 1);
        assert_eq!(b.cmds.pre, 0);
        // column-level stats: 1 miss, then 63 + 32 hits
        assert_eq!(b.stats.row_misses, 1);
        assert_eq!(b.stats.row_hits, 63 + 32);
    }

    #[test]
    fn fully_mapped_rows_hit_98_percent() {
        // The Fig. 11a headline: consecutive fully-mapped rows at 16
        // lanes give 64 accesses per ACT -> 63/64 = 98.4% hit rate.
        let mut b = Bank::new();
        let tm = t();
        let block = RowBlock { base_row: 0, full_rows: 100, tail_elems: 0 };
        b.mac_block(0, &block, 1024, &tm, 16, 5, 1);
        let rate = b.stats.hit_rate();
        assert!((rate - 63.0 / 64.0).abs() < 1e-9, "{rate}");
    }

    #[test]
    fn block_equals_segment_sweep_timing() {
        // The O(1) block path must agree with the explicit segment path.
        let tm = t();
        let mut b1 = Bank::new();
        let segs: Vec<RowSegment> =
            (0..20).map(|r| RowSegment { row: r, elems: 1024 }).collect();
        let f1 = b1.mac_sweep(0, &segs, &tm, 16, 5);
        let mut b2 = Bank::new();
        let block = RowBlock { base_row: 0, full_rows: 20, tail_elems: 0 };
        let f2 = b2.mac_block(0, &block, 1024, &tm, 16, 5, 1);
        assert_eq!(f1, f2);
        assert_eq!(b1.cmds.act, b2.cmds.act);
        assert_eq!(b1.cmds.mac_read_cycles, b2.cmds.mac_read_cycles);
        assert_eq!(b1.stats, b2.stats);
    }

    #[test]
    fn block_with_tail_equals_segments() {
        let tm = t();
        let mut b1 = Bank::new();
        let mut segs: Vec<RowSegment> =
            (0..5).map(|r| RowSegment { row: r, elems: 1024 }).collect();
        segs.push(RowSegment { row: 5, elems: 100 });
        let f1 = b1.mac_sweep(0, &segs, &tm, 16, 5);
        let mut b2 = Bank::new();
        let block = RowBlock { base_row: 0, full_rows: 5, tail_elems: 100 };
        let f2 = b2.mac_block(0, &block, 1024, &tm, 16, 5, 1);
        assert_eq!(f1, f2);
        assert_eq!(b1.stats, b2.stats);
        assert_eq!(b1.cmds.mac_read_cycles, b2.cmds.mac_read_cycles);
    }

    #[test]
    fn row_conflict_pays_pre_act() {
        let mut b = Bank::new();
        let tm = t();
        b.mac_sweep(0, &[RowSegment { row: 0, elems: 1024 }], &tm, 16, 5);
        let before = b.busy_until();
        let fin = b.mac_sweep(before, &[RowSegment { row: 1, elems: 16 }], &tm, 16, 5);
        // tRAS already satisfied by the 64-cycle MAC; PRE + ACT + fill + 1 chunk
        assert_eq!(fin - before, tm.trp + tm.trcd + 5 + 1);
        assert_eq!(b.cmds.pre, 1);
        assert_eq!(b.cmds.act, 2);
    }

    #[test]
    fn tras_enforced_on_fast_conflict() {
        let mut b = Bank::new();
        let tm = t();
        // Tiny segment: row open time << tRAS.
        b.mac_sweep(0, &[RowSegment { row: 0, elems: 16 }], &tm, 16, 5);
        let fin = b.mac_sweep(b.busy_until(), &[RowSegment { row: 9, elems: 16 }], &tm, 16, 5);
        // PRE cannot issue before opened_at + tRAS.
        assert!(fin >= tm.tras + tm.trp + tm.trcd + 5 + 1);
    }

    #[test]
    fn col_major_write_never_hits() {
        let mut b = Bank::new();
        let tm = t();
        b.write_col_major(0, 8, 100, 1, &tm);
        assert_eq!(b.stats.row_hits, 0);
        assert_eq!(b.stats.row_misses, 8);
        assert_eq!(b.cmds.pre, 8);
        assert_eq!(b.cmds.act, 8);
    }

    #[test]
    fn row_major_write_single_act() {
        let mut b = Bank::new();
        let tm = t();
        let fin = b.write_row_major(0, RowSegment { row: 2, elems: 768 }, &tm);
        assert_eq!(b.cmds.act, 1);
        assert_eq!(fin, tm.trcd + 48 + tm.twr); // 768/16 write chunks
    }

    #[test]
    fn refresh_closes_row_and_stalls() {
        let mut b = Bank::new();
        let tm = t();
        b.mac_sweep(0, &[RowSegment { row: 5, elems: 1024 }], &tm, 16, 5);
        let misses_before = b.stats.row_misses;
        let fin = b.refresh(b.busy_until(), &tm);
        assert_eq!(b.open_row(), None);
        // The next access to row 5 is a miss again.
        b.mac_sweep(fin, &[RowSegment { row: 5, elems: 16 }], &tm, 16, 5);
        assert_eq!(b.stats.row_misses, misses_before + 1);
    }

    #[test]
    fn prop_block_matches_segments() {
        check("mac_block == mac_sweep over same rows", 100, |rng| {
            let tm = t();
            let base = rng.gen_range(100) as u32;
            let full = rng.usize_in(0, 12) as u32;
            let tail = if rng.bool() { rng.usize_in(1, 1024) as u32 } else { 0 };
            if full == 0 && tail == 0 {
                return Ok(());
            }
            let lanes = 16u64;
            let mut segs: Vec<RowSegment> =
                (0..full).map(|i| RowSegment { row: base + i, elems: 1024 }).collect();
            if tail > 0 {
                segs.push(RowSegment { row: base + full, elems: tail });
            }
            let mut b1 = Bank::new();
            let f1 = b1.mac_sweep(7, &segs, &tm, lanes, 5);
            let mut b2 = Bank::new();
            let block = RowBlock { base_row: base, full_rows: full, tail_elems: tail };
            let f2 = b2.mac_block(7, &block, 1024, &tm, lanes, 5, 1);
            if f1 != f2 {
                return Err(format!("finish {f1} != {f2} (full={full} tail={tail})"));
            }
            if b1.stats != b2.stats {
                return Err(format!("stats {:?} != {:?}", b1.stats, b2.stats));
            }
            if b1.cmds.act != b2.cmds.act || b1.cmds.mac_read_cycles != b2.cmds.mac_read_cycles {
                return Err("command mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_pattern_matches_sweep() {
        // The O(1) pattern path must agree exactly with an explicit
        // segment sweep over the same distinct consecutive rows.
        check("mac_pattern == mac_sweep", 200, |rng| {
            let tm = t();
            let base = rng.gen_range(50) as u32;
            let reps = rng.usize_in(1, 40) as u32;
            let k = rng.usize_in(1, 4);
            let pattern: Vec<u32> =
                (0..k).map(|_| rng.usize_in(1, 1025) as u32).collect();
            let segs: Vec<RowSegment> = (0..reps as usize * k)
                .map(|i| RowSegment {
                    row: base + i as u32,
                    elems: pattern[i % k],
                })
                .collect();
            let mut b1 = Bank::new();
            let f1 = b1.mac_sweep(11, &segs, &tm, 16, 5);
            let mut b2 = Bank::new();
            let f2 = b2.mac_pattern(11, base, reps, &pattern, &tm, 16, 5, 1);
            if f1 != f2 {
                return Err(format!("finish {f1} != {f2} (reps={reps} pattern={pattern:?})"));
            }
            if b1.stats != b2.stats {
                return Err(format!("stats {:?} != {:?}", b1.stats, b2.stats));
            }
            if b1.cmds != b2.cmds {
                return Err(format!("cmds {:?} != {:?}", b1.cmds, b2.cmds));
            }
            if b1.open_row() != b2.open_row() {
                return Err("open_row mismatch".into());
            }
            // Continuation must also agree (opened_at consistency).
            let g1 = b1.mac_sweep(f1, &[RowSegment { row: 9999, elems: 16 }], &tm, 16, 5);
            let g2 = b2.mac_sweep(f2, &[RowSegment { row: 9999, elems: 16 }], &tm, 16, 5);
            if g1 != g2 {
                return Err(format!("continuation {g1} != {g2}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_col_major_closed_form_matches_loop() {
        // O(1) col-major write must equal an explicit per-element loop
        // built from single-element col-major writes.
        check("write_col_major closed form", 100, |rng| {
            let tm = t();
            let n = rng.usize_in(1, 40) as u32;
            let stride = rng.usize_in(1, 3) as u32;
            let mut fast = Bank::new();
            let f = fast.write_col_major(5, n, 100, stride, &tm);
            let mut slow = Bank::new();
            let mut now = 5;
            for i in 0..n {
                now = slow.write_col_major(now, 1, 100 + i * stride, 1, &tm);
            }
            if f != now {
                return Err(format!("n={n}: {f} != {now}"));
            }
            if fast.stats != slow.stats || fast.cmds != slow.cmds {
                return Err(format!("state mismatch n={n}: {:?} vs {:?} / {:?} vs {:?}",
                    fast.stats, slow.stats, fast.cmds, slow.cmds));
            }
            Ok(())
        });
    }

    /// Tentpole pin (chunked prefill): `mac_block` with `passes = T`
    /// equals a `mac_sweep` in which each row's segment appears `T`
    /// consecutive times — one ACT per row, every repetition an open-row
    /// hit. Full 1024-element rows keep the per-row occupancy above
    /// tRAS, the regime every weight block runs in.
    #[test]
    fn prop_block_passes_matches_repeated_sweep() {
        check("mac_block passes == repeated sweep", 100, |rng| {
            let tm = t();
            let base = rng.gen_range(100) as u32;
            let full = rng.usize_in(1, 8) as u32;
            let passes = rng.usize_in(1, 6) as u64;
            let mut segs: Vec<RowSegment> = Vec::new();
            for i in 0..full {
                for _ in 0..passes {
                    segs.push(RowSegment { row: base + i, elems: 1024 });
                }
            }
            let mut b1 = Bank::new();
            let f1 = b1.mac_sweep(9, &segs, &tm, 16, 5);
            let mut b2 = Bank::new();
            let block = RowBlock { base_row: base, full_rows: full, tail_elems: 0 };
            let f2 = b2.mac_block(9, &block, 1024, &tm, 16, 5, passes);
            if f1 != f2 {
                return Err(format!("finish {f1} != {f2} (full={full} passes={passes})"));
            }
            if b1.stats != b2.stats {
                return Err(format!("stats {:?} != {:?}", b1.stats, b2.stats));
            }
            if b1.cmds != b2.cmds {
                return Err(format!("cmds {:?} != {:?}", b1.cmds, b2.cmds));
            }
            // Amortization direction: T passes over the block cost less
            // than T separate single-pass blocks (row switches amortize).
            if passes > 1 && full > 1 {
                let mut b3 = Bank::new();
                let mut now = 9;
                for _ in 0..passes {
                    now = b3.mac_block(now, &block, 1024, &tm, 16, 5, 1);
                }
                let single = now - 9;
                if f2 - 9 >= single {
                    return Err(format!("no amortization: chunk {} !< {single}", f2 - 9));
                }
            }
            Ok(())
        });
    }

    /// Tentpole pin: `mac_pattern` with `passes = T` equals a
    /// `mac_sweep` with each pattern row repeated `T` consecutive times
    /// (arbitrary segment sizes — the KV-read shapes).
    #[test]
    fn prop_pattern_passes_matches_repeated_sweep() {
        check("mac_pattern passes == repeated sweep", 150, |rng| {
            let tm = t();
            let base = rng.gen_range(50) as u32;
            let reps = rng.usize_in(1, 12) as u32;
            let k = rng.usize_in(1, 4);
            let passes = rng.usize_in(1, 6) as u64;
            let pattern: Vec<u32> = (0..k).map(|_| rng.usize_in(1, 1025) as u32).collect();
            let mut segs: Vec<RowSegment> = Vec::new();
            for i in 0..reps as usize * k {
                for _ in 0..passes {
                    segs.push(RowSegment { row: base + i as u32, elems: pattern[i % k] });
                }
            }
            let mut b1 = Bank::new();
            let f1 = b1.mac_sweep(11, &segs, &tm, 16, 5);
            let mut b2 = Bank::new();
            let f2 = b2.mac_pattern(11, base, reps, &pattern, &tm, 16, 5, passes);
            if f1 != f2 {
                return Err(format!(
                    "finish {f1} != {f2} (reps={reps} passes={passes} pattern={pattern:?})"
                ));
            }
            if b1.stats != b2.stats {
                return Err(format!("stats {:?} != {:?}", b1.stats, b2.stats));
            }
            if b1.cmds != b2.cmds {
                return Err(format!("cmds {:?} != {:?}", b1.cmds, b2.cmds));
            }
            if b1.open_row() != b2.open_row() {
                return Err("open_row mismatch".into());
            }
            // Continuation must also agree (opened_at consistency).
            let g1 = b1.mac_sweep(f1, &[RowSegment { row: 9999, elems: 16 }], &tm, 16, 5);
            let g2 = b2.mac_sweep(f2, &[RowSegment { row: 9999, elems: 16 }], &tm, 16, 5);
            if g1 != g2 {
                return Err(format!("continuation {g1} != {g2}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_monotonic_time() {
        check("bank time monotonic", 200, |rng| {
            let tm = t();
            let mut b = Bank::new();
            let mut now = 0u64;
            for _ in 0..rng.usize_in(1, 30) {
                let segs: Vec<RowSegment> = (0..rng.usize_in(1, 5))
                    .map(|_| RowSegment {
                        row: rng.gen_range(4) as u32,
                        elems: rng.usize_in(1, 1025) as u32,
                    })
                    .collect();
                let fin = b.mac_sweep(now, &segs, &tm, 16, 5);
                if fin < now {
                    return Err(format!("time went backwards {fin} < {now}"));
                }
                now = fin;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_more_locality_fewer_acts() {
        // Sorting segments by row never increases activations.
        check("sorted segments minimize ACT", 100, |rng| {
            let tm = t();
            let mut segs: Vec<RowSegment> = (0..20)
                .map(|_| RowSegment { row: rng.gen_range(5) as u32, elems: 64 })
                .collect();
            let mut shuffled = Bank::new();
            shuffled.mac_sweep(0, &segs, &tm, 16, 5);
            segs.sort_by_key(|s| s.row);
            let mut sorted = Bank::new();
            sorted.mac_sweep(0, &segs, &tm, 16, 5);
            if sorted.cmds.act <= shuffled.cmds.act {
                Ok(())
            } else {
                Err(format!("{} > {}", sorted.cmds.act, shuffled.cmds.act))
            }
        });
    }
}
