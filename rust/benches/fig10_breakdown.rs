//! Bench: regenerate Fig. 10 (layer-wise latency breakdown, GPT3-small
//! and GPT3-XL). Paper: VMM dominates; arithmetic ~1.16% on GPT3-XL.
use pim_gpt::report::fig10_breakdown;
use pim_gpt::util::bench::bench;

fn main() {
    let tokens: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let mut out = None;
    bench("fig10: latency breakdown", 0, 1, || {
        out = Some(fig10_breakdown(tokens).unwrap());
    });
    let r = out.unwrap();
    println!("{}\n{}", r.title, r.rendered);
}
