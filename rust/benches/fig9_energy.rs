//! Bench: regenerate Fig. 9 (energy efficiency over GPU/CPU, 8 models).
//! Paper bands: GPU 339-1085x, CPU 890-1632x. (Same harness as Fig. 8 —
//! the paper derives both from one run; reprinted here for completeness.)
use pim_gpt::report::fig8_9_speedup_energy;
use pim_gpt::util::bench::bench;

fn main() {
    let tokens: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let mut out = None;
    bench("fig9: energy-efficiency sweep (8 models)", 0, 1, || {
        out = Some(fig8_9_speedup_energy(tokens).unwrap());
    });
    let r = out.unwrap();
    println!("{}\n{}", r.title, r.rendered);
}
