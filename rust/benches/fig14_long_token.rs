//! Bench: regenerate Fig. 14 (GPT3-XL latency at 1k/2k/4k/8k tokens).
//! Paper: long-token support beyond 8k, super-linear latency growth.
use pim_gpt::report::fig14_long_token;
use pim_gpt::util::bench::bench;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let lengths: &[u64] = if full { &[1024, 2048, 4096, 8096] } else { &[256, 512, 1024, 2048] };
    let mut out = None;
    bench("fig14: long-token sweep (GPT3-XL)", 0, 1, || {
        out = Some(fig14_long_token(lengths).unwrap());
    });
    let r = out.unwrap();
    println!("{}\n{}", r.title, r.rendered);
}
