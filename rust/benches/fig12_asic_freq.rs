//! Bench: regenerate Fig. 12 (ASIC frequency sensitivity). Paper: worst
//! +20% at 100 MHz; larger models less sensitive.
use pim_gpt::report::fig12_asic_freq;
use pim_gpt::util::bench::bench;

fn main() {
    let tokens: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(32);
    let mut out = None;
    bench("fig12: ASIC frequency sweep (8 models x 4 freqs)", 0, 1, || {
        out = Some(fig12_asic_freq(tokens).unwrap());
    });
    let r = out.unwrap();
    println!("{}\n{}", r.title, r.rendered);
}
