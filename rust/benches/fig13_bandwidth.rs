//! Bench: regenerate Fig. 13 (interface data-rate sensitivity). Paper:
//! ~1.5x at 2 Gb/s, ~2x at 1 Gb/s on average.
use pim_gpt::report::fig13_bandwidth;
use pim_gpt::util::bench::bench;

fn main() {
    let tokens: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(32);
    let mut out = None;
    bench("fig13: bandwidth sweep (8 models x 5 rates)", 0, 1, || {
        out = Some(fig13_bandwidth(tokens).unwrap());
    });
    let r = out.unwrap();
    println!("{}\n{}", r.title, r.rendered);
}
