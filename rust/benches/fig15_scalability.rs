//! Bench: regenerate Fig. 15 (scalability: MAC lanes 16->64 gives
//! 1.8-2.0x, paper; channel count scales near-linearly).
use pim_gpt::report::fig15_scalability;
use pim_gpt::util::bench::bench;

fn main() {
    let tokens: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(32);
    let mut out = None;
    bench("fig15: scalability sweep", 0, 1, || {
        out = Some(fig15_scalability(tokens).unwrap());
    });
    let r = out.unwrap();
    println!("{}\n{}", r.title, r.rendered);
}
