//! Ablation of the two mapping design choices DESIGN.md calls out
//! (paper §IV.B, Fig. 6):
//!
//! 1. **Head concatenation** (`maxRowHit`): map the head-concatenated
//!    matrix as fully-packed consecutive rows vs. mapping each attention
//!    head separately (per-head tail rows, scattered segments).
//! 2. **Open-row policy**: keep the row open between consecutive MAC
//!    bursts vs. a close-row policy (modeled by forcing a row switch
//!    after every burst).
//!
//! Both ablations run one channel-level VMM of GPT2-small's W_qkv slice
//! and compare cycles, ACTs and row-hit rate.

use pim_gpt::config::HwConfig;
use pim_gpt::dram::bank::RowBlock;
use pim_gpt::dram::{RowSegment, TimingCycles};
use pim_gpt::pim::{Channel, UnitWork, VmmPlan};
use pim_gpt::util::bench::bench;

fn run_plan(cfg: &HwConfig, plan: &VmmPlan) -> (u64, u64, f64) {
    let t = TimingCycles::from_config(cfg);
    let mut ch = Channel::new(cfg);
    let e = ch.execute_vmm(cfg, &t, 0, plan);
    let (stats, cmds) = ch.stats();
    (e.finish, cmds.act, stats.hit_rate())
}

fn main() {
    let cfg = HwConfig::paper_baseline();
    // One bank's share of GPT2-small W_qkv: 768 x 18 columns = 13,824
    // elements = 13.5 fully-packed rows.
    let elems_per_bank: u64 = 768 * 18;
    let row_elems = cfg.gddr6.row_elems();
    let full_rows = (elems_per_bank / row_elems) as u32;
    let tail = (elems_per_bank % row_elems) as u32;
    let n_banks = cfg.gddr6.banks_per_channel;

    // (1a) concatenated: one contiguous block per bank.
    let concat_plan = VmmPlan {
        bank_work: (0..n_banks)
            .map(|_| UnitWork::Block(RowBlock { base_row: 0, full_rows, tail_elems: tail }))
            .collect(),
        input_elems: 768,
        output_elems: 18 * n_banks as u64,
        passes: 1,
    };

    // (1b) per-head: 12 heads, each head's share is a separate region
    // with its own partial tail row (no row sharing across heads).
    let per_head = elems_per_bank / 12;
    let head_rows = (per_head / row_elems) as u32; // 1 full row ...
    let head_tail = (per_head % row_elems) as u32; // ... + 128-elem tail
    let no_concat_plan = VmmPlan {
        bank_work: (0..n_banks)
            .map(|_| {
                let mut segs = Vec::new();
                for h in 0..12u32 {
                    let base = h * (head_rows + 1 + (head_tail > 0) as u32);
                    for r in 0..head_rows {
                        segs.push(RowSegment { row: base + r, elems: row_elems as u32 });
                    }
                    if head_tail > 0 {
                        segs.push(RowSegment { row: base + head_rows, elems: head_tail });
                    }
                }
                UnitWork::Segments(segs)
            })
            .collect(),
        input_elems: 768,
        output_elems: 18 * n_banks as u64,
        passes: 1,
    };

    // (2) close-row policy: a row switch after every 256-element burst.
    let close_row_plan = VmmPlan {
        bank_work: (0..n_banks)
            .map(|_| {
                let mut segs = Vec::new();
                let bursts = elems_per_bank / 256;
                for b in 0..bursts as u32 {
                    // alternate rows to force PRE+ACT between bursts
                    segs.push(RowSegment { row: 1000 + (b % 2), elems: 256 });
                }
                UnitWork::Segments(segs)
            })
            .collect(),
        input_elems: 768,
        output_elems: 18 * n_banks as u64,
        passes: 1,
    };

    println!("== mapping ablation: one channel VMM over GPT2-small W_qkv share ==\n");
    let mut results = Vec::new();
    for (name, plan) in [
        ("head-concat + open-row (paper)", &concat_plan),
        ("per-head mapping (no concat)", &no_concat_plan),
        ("close-row policy", &close_row_plan),
    ] {
        let mut out = (0, 0, 0.0);
        bench(&format!("ablation: {name}"), 2, 50, || {
            out = run_plan(&cfg, plan);
        });
        results.push((name, out));
    }
    println!("\n{:<36} {:>9} {:>6} {:>9}", "variant", "cycles", "ACTs", "hit rate");
    let base = results[0].1 .0 as f64;
    for (name, (cycles, acts, hit)) in &results {
        println!(
            "{:<36} {:>9} {:>6} {:>8.2}%  ({:.2}x vs paper mapping)",
            name,
            cycles,
            acts,
            100.0 * hit,
            *cycles as f64 / base
        );
    }
}
