//! Bench: regenerate Table II (vs SpAtten/TransPIM/DFX). Paper anchor:
//! PIM-GPT 89x speedup / 618x energy on GPT2-medium, 1024 tokens.
use pim_gpt::report::table2_comparison;
use pim_gpt::util::bench::bench;

fn main() {
    let tokens: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1024);
    let mut out = None;
    bench("table2: accelerator comparison (GPT2-medium)", 0, 1, || {
        out = Some(table2_comparison(tokens).unwrap());
    });
    let r = out.unwrap();
    println!("{}\n{}", r.title, r.rendered);
}
