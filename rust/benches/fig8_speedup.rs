//! Bench: regenerate Fig. 8 (speedup over GPU/CPU, 8 models) and time
//! the harness itself. Paper bands: GPU 41-137x, CPU 631-1074x.
use pim_gpt::report::fig8_9_speedup_energy;
use pim_gpt::util::bench::bench;

fn main() {
    let tokens: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let mut out = None;
    bench("fig8: speedup sweep (8 models)", 0, 1, || {
        out = Some(fig8_9_speedup_energy(tokens).unwrap());
    });
    let r = out.unwrap();
    println!("{}\n{}", r.title, r.rendered);
}
