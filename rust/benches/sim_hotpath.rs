//! Microbenchmarks of the simulator hot path (the §Perf targets):
//! per-token decode cost across model sizes and context lengths, the
//! mapping stage, and graph compilation.
use pim_gpt::compiler::compile;
use pim_gpt::config::HwConfig;
use pim_gpt::mapping::ModelMapping;
use pim_gpt::model::gpt::by_name;
use pim_gpt::model::DecodeGraph;
use pim_gpt::sim::Simulator;
use pim_gpt::util::bench::{bench, black_box};

fn main() {
    let cfg = HwConfig::paper_baseline();

    for name in ["gpt2-small", "gpt3-xl"] {
        let m = by_name(name).unwrap();
        bench(&format!("mapping::build {name}"), 1, 5, || {
            black_box(ModelMapping::build(&m, &cfg).unwrap());
        });
        bench(&format!("graph+compile {name} pos=1023"), 2, 20, || {
            let g = DecodeGraph::build(&m, 1023);
            black_box(compile(&g, &cfg).unwrap());
        });
        let mut sim = Simulator::new(&m, &cfg).unwrap();
        let mut pos = 0u64;
        bench(&format!("sim::decode_step {name} (growing ctx)"), 8, 256, || {
            sim.decode_step(pos % m.max_seq as u64).unwrap();
            pos += 1;
        });
        let mut sim2 = Simulator::new(&m, &cfg).unwrap();
        bench(&format!("sim::generate {name} 64 tokens"), 0, 3, || {
            black_box(sim2.generate(64).unwrap());
        });
    }
}
