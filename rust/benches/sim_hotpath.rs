//! Microbenchmarks of the simulator hot path (the §Perf targets):
//! per-token decode cost across model sizes and context lengths, the
//! mapping stage, graph compilation, the multi-request scheduler
//! (simulated throughput at K ∈ {1, 2, 4} + program-cache hit rate),
//! the open-loop Poisson arrival sweep (tail latency vs load), the
//! scheduling-policy sweep at K=4 (fcfs / srf / fair / slo), the
//! tracing on/off sweep (the observability tax, exported to
//! `BENCH_sim_hotpath.json` at the repo root), and the profiler's
//! cost-table calibration (relative-error envelope per model, exported
//! to `BENCH_calibration.json`).
use pim_gpt::compiler::compile;
use pim_gpt::config::HwConfig;
use pim_gpt::mapping::{ModelMapping, PartitionStrategy};
use pim_gpt::model::gpt::by_name;
use pim_gpt::model::DecodeGraph;
use pim_gpt::sim::arrivals::{self, ArrivalSpec};
use pim_gpt::sim::{FleetSim, MultiSim, Simulator, StreamSpec};
use pim_gpt::util::bench::{bench, black_box};

fn main() {
    let cfg = HwConfig::paper_baseline();

    for name in ["gpt2-small", "gpt3-xl"] {
        let m = by_name(name).unwrap();
        bench(&format!("mapping::build {name}"), 1, 5, || {
            black_box(ModelMapping::build(&m, &cfg).unwrap());
        });
        bench(&format!("graph+compile {name} pos=1023"), 2, 20, || {
            let g = DecodeGraph::build(&m, 1023);
            black_box(compile(&g, &cfg).unwrap());
        });
        let mut sim = Simulator::new(&m, &cfg).unwrap();
        let mut pos = 0u64;
        bench(&format!("sim::decode_step {name} (growing ctx, cached)"), 8, 256, || {
            sim.decode_step(pos % m.max_seq as u64).unwrap();
            pos += 1;
        });
        let mut sim2 = Simulator::new(&m, &cfg).unwrap();
        bench(&format!("sim::generate {name} 64 tokens"), 0, 3, || {
            black_box(sim2.generate(64).unwrap());
        });
    }

    // Program-cache amortization: a 256-token generation compiles at
    // most once per position regime.
    {
        let m = by_name("gpt2-small").unwrap();
        let mut sim = Simulator::new(&m, &cfg).unwrap();
        sim.generate(256).unwrap();
        sim.finalize_stats();
        println!(
            "program cache      : {:.1}% hit rate over 256 tokens ({} compiles, {} hits)",
            100.0 * sim.stats.program_cache_hit_rate(),
            sim.stats.program_cache_misses,
            sim.stats.program_cache_hits,
        );
    }

    // Multi-request scheduler: same mixed gpt2-small request set served
    // FIFO (K=1) vs interleaved (K=2, K=4). Reports wall time of the
    // *host* (bench harness) and simulated tokens/s of the *hardware*.
    let m = by_name("gpt2-small").unwrap();
    let specs: Vec<StreamSpec> = (0..8).map(|id| StreamSpec::new(id, 8 + 4 * (id % 3))).collect();
    let total_tokens: u64 = specs.iter().map(|s| s.n_tokens).sum();
    for k in [1usize, 2, 4] {
        let kcfg = HwConfig::paper_baseline().with_max_streams(k);
        bench(&format!("sim::multi gpt2-small K={k} (8 mixed reqs)"), 1, 5, || {
            let mut ms = MultiSim::new(&m, &kcfg).unwrap();
            for s in &specs {
                ms.submit(*s).unwrap();
            }
            black_box(ms.run_all().unwrap());
        });
        let mut ms = MultiSim::new(&m, &kcfg).unwrap();
        for s in &specs {
            ms.submit(*s).unwrap();
        }
        ms.run_all().unwrap();
        ms.finalize_stats();
        let secs = ms.clock() as f64 / (kcfg.gddr6.freq_ghz * 1e9);
        println!(
            "  K={k}: simulated {total_tokens} tokens in {:.3} ms -> {:.0} tok/s, \
             pim util {:.1}%, asic util {:.1}%, cache hit {:.1}%",
            secs * 1e3,
            total_tokens as f64 / secs,
            100.0 * ms.stats.pim_utilization(kcfg.total_mac_units() as u64),
            100.0 * ms.stats.asic_utilization(),
            100.0 * ms.stats.program_cache_hit_rate(),
        );
        println!(
            "       kv slots {} (peak in use {}), admission blocked {} times",
            ms.stats.kv_slots, ms.stats.peak_slots_in_use, ms.stats.admission_blocked,
        );
    }

    // KV-capacity admission: the same 8-request set on a memory that
    // only fits ~2 of the 4 requested contexts — admission degrades and
    // blocks on slot availability instead of oversubscribing the cache.
    {
        let mut tight = HwConfig::paper_baseline().with_max_streams(4);
        tight.gddr6.capacity_gbit = 0.34;
        let mut ms = MultiSim::new(&m, &tight).unwrap();
        let shortfall = ms
            .mapping
            .kv_shortfall
            .as_ref()
            .map(|r| r.to_string())
            .unwrap_or_else(|| "none".into());
        for s in &specs {
            ms.submit(*s).unwrap();
        }
        ms.run_all().unwrap();
        ms.finalize_stats();
        let queued = ms.stats.streams.iter().filter(|s| s.queue_cycles > 0).count();
        println!(
            "sim::multi capacity-limited (0.34 Gb/ch): {} of 4 requested slots, \
             {queued}/8 requests queued, blocked {} times\n  shortfall: {shortfall}",
            ms.stats.kv_slots, ms.stats.admission_blocked,
        );
    }

    // Open-loop arrival sweep: Poisson arrivals at 0.5x / 1x / 2x of the
    // batch capacity (capacity = n_requests / batch makespan), reporting
    // queue/TTFT/e2e tail percentiles. Past load 1.0 the tail blows up —
    // the curve SLO-aware admission policies would act on.
    {
        let kcfg = HwConfig::paper_baseline().with_max_streams(4);
        let freq_hz = kcfg.gddr6.freq_ghz * 1e9;
        let mapping = ModelMapping::build(&m, &kcfg).unwrap();
        let n_req = 8usize;
        let run = |at: &[u64]| {
            let mut ms = MultiSim::from_mapping(&m, &kcfg, mapping.clone());
            for (id, &a) in at.iter().enumerate() {
                let spec =
                    StreamSpec { id: id as u64, n_tokens: 8, prompt_tokens: 1, arrival_cycle: a };
                ms.submit(spec).unwrap();
            }
            ms.run_all().unwrap();
            ms.finalize_stats();
            (ms.clock(), ms.stats.latency_report().unwrap())
        };
        let (makespan, _) = run(&vec![0u64; n_req]);
        println!("sim::multi open-loop gpt2-small K=4 ({n_req} reqs x 8 tokens), us per stage:");
        for load in [0.5, 1.0, 2.0] {
            let rate_per_s = load * n_req as f64 * freq_hz / makespan as f64;
            let spec = ArrivalSpec::Poisson { rate_per_s };
            let at = arrivals::generate(&spec, n_req, kcfg.gddr6.freq_ghz, 7).unwrap();
            let (_, lat) = run(&at);
            let us = |c: u64| c as f64 / (freq_hz / 1e6);
            println!(
                "  load {load:.1} ({rate_per_s:.0} req/s): queue p50/p99 {:.1}/{:.1}, \
                 ttft p50/p99 {:.1}/{:.1}, e2e p99 {:.1}",
                us(lat.queue.p50),
                us(lat.queue.p99),
                us(lat.ttft.p50),
                us(lat.ttft.p99),
                us(lat.e2e.p99),
            );
        }
    }

    // Chunked-prefill sweep (K=4 Poisson load): the same 256-token-
    // prompt request set served at prefill chunk sizes {1, 8, 32, 128}.
    // chunk=1 is token-by-token prefill (the historical path); larger
    // chunks amortize weight-row activations, GB staging and ASIC
    // pipeline fills over the chunk, shrinking TTFT (first *generated*
    // token) and makespan at the cost of longer per-instruction
    // head-of-line blocking.
    {
        let kcfg = HwConfig::paper_baseline().with_max_streams(4);
        let freq_hz = kcfg.gddr6.freq_ghz * 1e9;
        let mapping = ModelMapping::build(&m, &kcfg).unwrap();
        let n_req = 8usize;
        let (prompt, gen) = (256u64, 8u64);
        // Offered load calibrated to the chunk=32 batch makespan.
        let mut batch = MultiSim::from_mapping(&m, &kcfg, mapping.clone());
        for id in 0..n_req as u64 {
            batch.submit(StreamSpec::with_prompt(id, prompt, gen)).unwrap();
        }
        batch.run_all().unwrap();
        let rate_per_s = n_req as f64 * freq_hz / batch.clock() as f64;
        let at = arrivals::generate(
            &ArrivalSpec::Poisson { rate_per_s },
            n_req,
            kcfg.gddr6.freq_ghz,
            7,
        )
        .unwrap();
        println!(
            "sim::multi prefill sweep gpt2-small K=4 ({n_req} reqs x {prompt}-token \
             prompts +{gen} gen, Poisson 1.0x):"
        );
        for chunk in [1u64, 8, 32, 128] {
            let ccfg = kcfg.clone().with_prefill_chunk(chunk);
            bench(&format!("sim::multi prefill chunk={chunk} gpt2-small K=4"), 1, 3, || {
                let mut ms = MultiSim::from_mapping(&m, &ccfg, mapping.clone());
                for (id, &a) in at.iter().enumerate() {
                    let mut spec = StreamSpec::with_prompt(id as u64, prompt, gen);
                    spec.arrival_cycle = a;
                    ms.submit(spec).unwrap();
                }
                black_box(ms.run_all().unwrap());
            });
            let mut ms = MultiSim::from_mapping(&m, &ccfg, mapping.clone());
            for (id, &a) in at.iter().enumerate() {
                let mut spec = StreamSpec::with_prompt(id as u64, prompt, gen);
                spec.arrival_cycle = a;
                ms.submit(spec).unwrap();
            }
            ms.run_all().unwrap();
            ms.finalize_stats();
            let us = |c: u64| c as f64 / (freq_hz / 1e6);
            let lat = ms.stats.latency_report().unwrap();
            println!(
                "  chunk {chunk:>3}: makespan {:.1} us, ttft p50/p99 {:.1}/{:.1} us, \
                 {} prefill chunks, prefill/decode {:.1}/{:.1} us summed",
                us(ms.clock()),
                us(lat.ttft.p50),
                us(lat.ttft.p99),
                ms.stats.prefill_chunks,
                us(ms.stats.prefill_cycles),
                us(ms.stats.decode_cycles),
            );
        }
    }

    // Scheduling-policy sweep (K=4): one mixed Poisson request set
    // served under every pick/admission policy — host cost of the
    // policy layer plus the simulated makespan / tail-latency / shed
    // trade-off each policy buys.
    {
        let kcfg = HwConfig::paper_baseline().with_max_streams(4);
        let freq_hz = kcfg.gddr6.freq_ghz * 1e9;
        let mapping = ModelMapping::build(&m, &kcfg).unwrap();
        let lens: Vec<u64> = (0..8u64).map(|i| 4 + 4 * (i % 3)).collect();
        let submit_all = |ms: &mut MultiSim, at: &[u64]| {
            for (id, (&n, &a)) in lens.iter().zip(at.iter()).enumerate() {
                let spec =
                    StreamSpec { id: id as u64, n_tokens: n, prompt_tokens: 1, arrival_cycle: a };
                ms.submit(spec).unwrap();
            }
        };
        // Batch makespan calibrates the offered rate and the SLO budget.
        let mut batch = MultiSim::from_mapping(&m, &kcfg, mapping.clone());
        submit_all(&mut batch, &[0u64; 8]);
        batch.run_all().unwrap();
        let makespan = batch.clock();
        let rate_per_s = 1.5 * 8.0 * freq_hz / makespan as f64;
        let at =
            arrivals::generate(&ArrivalSpec::Poisson { rate_per_s }, 8, kcfg.gddr6.freq_ghz, 7)
                .unwrap();
        let budget = (makespan / 8).max(1) * 4;
        let slo = format!("slo:{budget}");
        println!(
            "sim::multi policy sweep gpt2-small K=4 (8 mixed reqs, Poisson 1.5x, \
             slo budget {budget} cycles):"
        );
        for policy in ["fcfs", "srf", "fair", slo.as_str()] {
            let mut cfg = kcfg.clone();
            cfg.sched.set_policy_str(policy).unwrap();
            bench(&format!("sim::multi policy={policy} gpt2-small K=4"), 1, 5, || {
                let mut ms = MultiSim::from_mapping(&m, &cfg, mapping.clone());
                submit_all(&mut ms, &at);
                black_box(ms.run_all().unwrap());
            });
            let mut ms = MultiSim::from_mapping(&m, &cfg, mapping.clone());
            submit_all(&mut ms, &at);
            ms.run_all().unwrap();
            ms.finalize_stats();
            let us = |c: u64| c as f64 / (freq_hz / 1e6);
            match ms.stats.latency_report() {
                Some(lat) => println!(
                    "  {:>9}: makespan {:.1} us, ttft p50/p99 {:.1}/{:.1} us, \
                     e2e p99 {:.1} us, rejected {}",
                    policy,
                    us(ms.clock()),
                    us(lat.ttft.p50),
                    us(lat.ttft.p99),
                    us(lat.e2e.p99),
                    ms.stats.rejected,
                ),
                None => println!("  {policy:>9}: every request rejected"),
            }
        }
    }

    // Batched-decode sweep (batching on/off x K in {1, 2, 4}, Poisson
    // load): ready decode tokens across streams fuse into one
    // multi-pass weight sweep, so busy-cycle tokens/s climbs with K
    // while the unbatched schedule stays flat. The bench timings carry
    // the host cost of batch formation; the printed lines carry the
    // simulated capacity win and the sweep occupancy.
    {
        let freq_hz = cfg.gddr6.freq_ghz * 1e9;
        let map_cfg = HwConfig::paper_baseline().with_max_streams(4);
        let mapping = ModelMapping::build(&m, &map_cfg).unwrap();
        let n_req = 8usize;
        // Rate ~1.5x the unbatched K=4 capacity keeps the slots saturated.
        let mut batch = MultiSim::from_mapping(&m, &map_cfg, mapping.clone());
        for id in 0..n_req as u64 {
            batch.submit(StreamSpec::new(id, 8)).unwrap();
        }
        batch.run_all().unwrap();
        let rate_per_s = 1.5 * n_req as f64 * freq_hz / batch.clock() as f64;
        let at =
            arrivals::generate(&ArrivalSpec::Poisson { rate_per_s }, n_req, cfg.gddr6.freq_ghz, 7)
                .unwrap();
        let submit_all = |ms: &mut MultiSim| {
            for (id, &a) in at.iter().enumerate() {
                let spec =
                    StreamSpec { id: id as u64, n_tokens: 8, prompt_tokens: 1, arrival_cycle: a };
                ms.submit(spec).unwrap();
            }
        };
        println!(
            "sim::multi batched-decode sweep gpt2-small ({n_req} reqs x 8 tokens, Poisson 1.5x):"
        );
        for k in [1usize, 2, 4] {
            for batch_on in [false, true] {
                let kcfg =
                    HwConfig::paper_baseline().with_max_streams(k).with_batch_decode(batch_on);
                let tag = if batch_on { "on" } else { "off" };
                bench(&format!("sim::multi batch={tag} K={k} gpt2-small"), 1, 5, || {
                    let mut ms = MultiSim::from_mapping(&m, &kcfg, mapping.clone());
                    submit_all(&mut ms);
                    black_box(ms.run_all().unwrap());
                });
                let mut ms = MultiSim::from_mapping(&m, &kcfg, mapping.clone());
                submit_all(&mut ms);
                ms.run_all().unwrap();
                ms.finalize_stats();
                let busy_s = ms.stats.busy_seconds(cfg.gddr6.freq_ghz);
                println!(
                    "  K={k} batch={tag:>3}: {:.0} tok/s (busy-cycle basis), {} fused sweeps \
                     (mean {:.2} / max {}), {} solo decode steps",
                    ms.stats.tokens as f64 / busy_s,
                    ms.stats.fused_sweeps,
                    ms.stats.mean_decode_batch(),
                    ms.stats.max_decode_batch,
                    ms.stats.solo_decode_steps,
                );
            }
        }
    }

    // Paged-KV sweep (paging on/off x K in {1, 4}) on a capacity-
    // squeezed memory (0.34 Gb/ch fits ~2 whole gpt2-small contexts):
    // the slot engine degrades to whole-context grants while the paged
    // engine admits on expected footprint, so short-prompt streams
    // co-reside that the slot engine would queue. The bench timings
    // carry the host cost of page-table indirection; the printed lines
    // carry the simulated grant / occupancy / fault counters.
    {
        let freq_hz = cfg.gddr6.freq_ghz * 1e9;
        let specs: Vec<StreamSpec> =
            (0..8u64).map(|id| StreamSpec::with_prompt(id, 8, 8 + 4 * (id % 3))).collect();
        println!("sim::multi paged-KV sweep gpt2-small 0.34 Gb/ch (8 short-prompt reqs):");
        for k in [1usize, 4] {
            for paged in [false, true] {
                let mut pcfg = HwConfig::paper_baseline().with_max_streams(k);
                pcfg.gddr6.capacity_gbit = 0.34;
                if paged {
                    pcfg = pcfg.with_kv_paging(true).with_kv_page_tokens(128);
                }
                let tag = if paged { "on" } else { "off" };
                bench(&format!("sim::multi paging={tag} K={k} gpt2-small"), 1, 5, || {
                    let mut ms = MultiSim::new(&m, &pcfg).unwrap();
                    for s in &specs {
                        ms.submit(*s).unwrap();
                    }
                    black_box(ms.run_all().unwrap());
                });
                let mut ms = MultiSim::new(&m, &pcfg).unwrap();
                for s in &specs {
                    ms.submit(*s).unwrap();
                }
                ms.run_all().unwrap();
                ms.finalize_stats();
                let us = |c: u64| c as f64 / (freq_hz / 1e6);
                let lat = ms.stats.latency_report().unwrap();
                let grant = if paged {
                    format!("{} frames", ms.stats.kv_pages)
                } else {
                    format!("{} slots", ms.stats.kv_slots)
                };
                println!(
                    "  K={k} paging={tag:>3}: makespan {:.1} us, ttft p99 {:.1} us, \
                     grant {grant} (peak streams {}), {} faults / {} preemptions",
                    us(ms.clock()),
                    us(lat.ttft.p99),
                    ms.stats.peak_slots_in_use,
                    ms.stats.page_faults,
                    ms.stats.preemptions,
                );
            }
        }
    }

    // Multi-device fleet sweep (N in {1, 2, 4} x both partition
    // strategies, K=4 Poisson load): the same gpt2-small request set
    // served across partitioned packages. The bench timings carry the
    // host cost of the step-cost composition (compile + scratch walk,
    // memoized per context); the printed lines carry the simulated
    // makespan, the interconnect cycles the strategy pays, and the
    // per-device busy split.
    {
        let freq_hz = cfg.gddr6.freq_ghz * 1e9;
        let n_req = 8usize;
        let base = HwConfig::paper_baseline().with_max_streams(4);
        let mut batch = MultiSim::new(&m, &base).unwrap();
        for id in 0..n_req as u64 {
            batch.submit(StreamSpec::new(id, 8)).unwrap();
        }
        batch.run_all().unwrap();
        let rate_per_s = 1.5 * n_req as f64 * freq_hz / batch.clock() as f64;
        let at =
            arrivals::generate(&ArrivalSpec::Poisson { rate_per_s }, n_req, cfg.gddr6.freq_ghz, 7)
                .unwrap();
        println!("sim::fleet sweep gpt2-small K=4 ({n_req} reqs x 8 tokens, Poisson 1.5x):");
        for devices in [1usize, 2, 4] {
            for strategy in
                [PartitionStrategy::LayerPipeline, PartitionStrategy::TensorParallel]
            {
                if devices == 1 && strategy == PartitionStrategy::TensorParallel {
                    continue; // identical to the N=1 pipeline row
                }
                let fcfg = base.clone().with_devices(devices).with_partition(strategy);
                let submit_all = |fleet: &mut FleetSim| {
                    for (id, &a) in at.iter().enumerate() {
                        let spec = StreamSpec {
                            id: id as u64,
                            n_tokens: 8,
                            prompt_tokens: 1,
                            arrival_cycle: a,
                        };
                        fleet.submit(spec).unwrap();
                    }
                };
                let tag = if devices == 1 { "single".to_string() } else { strategy.to_string() };
                bench(&format!("sim::fleet N={devices} {tag} gpt2-small K=4"), 1, 5, || {
                    let mut fleet = FleetSim::new(&m, &fcfg).unwrap();
                    submit_all(&mut fleet);
                    black_box(fleet.run_all().unwrap());
                });
                let mut fleet = FleetSim::new(&m, &fcfg).unwrap();
                submit_all(&mut fleet);
                fleet.run_all().unwrap();
                let clock = fleet.clock();
                let s = fleet.finalize_stats();
                let us = |c: u64| c as f64 / (freq_hz / 1e6);
                let busy: Vec<String> =
                    s.device_busy_cycles.iter().map(|b| format!("{:.1}", us(*b))).collect();
                println!(
                    "  N={devices} {tag:>14}: makespan {:.1} us, {:.0} tok/s, \
                     link {:.1} us, device busy us [{}]",
                    us(clock),
                    s.tokens as f64 * freq_hz / clock as f64,
                    us(s.link_transfer_cycles),
                    busy.join(", "),
                );
            }
        }
    }

    // Tracing on/off sweep (K=4 Poisson): the observability tax. Off is
    // the default — a dead branch per lifecycle edge, no allocation —
    // and the simulated schedule is cycle-identical either way (checked
    // below). The JSONL sink buffers one flat object per event, the
    // Chrome sink defers all rendering to the end of the run; the
    // documented bound is JSONL min wall time <= 5x untraced (in
    // practice it sits well under 2x — the 5x guard only screens
    // regressions through CI noise). Results land in
    // BENCH_sim_hotpath.json at the repo root for trend tracking.
    {
        use pim_gpt::util::json::Json;
        let kcfg = HwConfig::paper_baseline().with_max_streams(4);
        let freq_hz = kcfg.gddr6.freq_ghz * 1e9;
        let mapping = ModelMapping::build(&m, &kcfg).unwrap();
        let n_req = 8usize;
        let mut batch = MultiSim::from_mapping(&m, &kcfg, mapping.clone());
        for id in 0..n_req as u64 {
            batch.submit(StreamSpec::new(id, 8)).unwrap();
        }
        batch.run_all().unwrap();
        let rate_per_s = 1.5 * n_req as f64 * freq_hz / batch.clock() as f64;
        let at =
            arrivals::generate(&ArrivalSpec::Poisson { rate_per_s }, n_req, cfg.gddr6.freq_ghz, 7)
                .unwrap();
        println!(
            "sim::multi tracing sweep gpt2-small K=4 ({n_req} reqs x 8 tokens, Poisson 1.5x):"
        );
        let run_once = |tcfg: &HwConfig| {
            let mut ms = MultiSim::from_mapping(&m, tcfg, mapping.clone());
            for (id, &a) in at.iter().enumerate() {
                let spec =
                    StreamSpec { id: id as u64, n_tokens: 8, prompt_tokens: 1, arrival_cycle: a };
                ms.submit(spec).unwrap();
            }
            ms.run_all().unwrap();
            ms.finalize_stats();
            let events = ms.trace_counts().submits
                + ms.trace_counts().releases
                + ms.trace_counts().admits
                + ms.trace_counts().prefill_chunks
                + ms.trace_counts().solo_decode_steps
                + ms.trace_counts().fused_sweeps
                + ms.trace_counts().retires;
            (ms.clock(), events)
        };
        let mut rows: Vec<Json> = Vec::new();
        let mut clocks: Vec<u64> = Vec::new();
        let mut mins: Vec<(String, f64)> = Vec::new();
        for spec in ["off", "jsonl:t.jsonl", "chrome:t.json"] {
            let tcfg = kcfg.clone().with_trace(spec);
            let tag = spec.split(':').next().unwrap().to_string();
            let r = bench(&format!("sim::multi trace={tag} gpt2-small K=4"), 2, 8, || {
                black_box(run_once(&tcfg));
            });
            let (clock, events) = run_once(&tcfg);
            clocks.push(clock);
            mins.push((tag.clone(), r.min_s));
            rows.push(Json::obj(vec![
                ("trace", tag.as_str().into()),
                ("iters", r.iters.into()),
                ("mean_s", r.mean_s.into()),
                ("min_s", r.min_s.into()),
                ("max_s", r.max_s.into()),
                ("makespan_cycles", clock.into()),
                ("events", events.into()),
            ]));
        }
        assert!(
            clocks.iter().all(|&c| c == clocks[0]),
            "tracing changed the simulated makespan: {clocks:?}"
        );
        let off = mins[0].1;
        let jsonl = mins[1].1;
        let overhead = jsonl / off;
        println!(
            "  jsonl overhead {overhead:.2}x untraced (bound 5x), \
             makespan {} cycles in every mode",
            clocks[0]
        );
        assert!(
            overhead <= 5.0,
            "jsonl tracing overhead {overhead:.2}x exceeds the documented 5x bound"
        );
        let out = Json::obj(vec![
            ("bench", "sim_hotpath".into()),
            ("workload", "gpt2-small K=4, 8 reqs x 8 tokens, Poisson 1.5x".into()),
            ("jsonl_overhead_x", overhead.into()),
            ("bound_x", Json::from(5.0)),
            ("runs", Json::Arr(rows)),
        ]);
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sim_hotpath.json");
        std::fs::write(path, format!("{out}\n")).expect("write BENCH_sim_hotpath.json");
        println!("  wrote {path}");
    }

    // Cost-table calibration: train a per-span cost table on a small
    // profiled workload per model, cross-validate `predict()` against
    // fresh cycle-accurate single-request runs, and record the
    // relative-error envelope to BENCH_calibration.json for trend
    // tracking (the acceptance bound — mean <= 5%, max <= 15% — is
    // pinned in tests/integration_profile.rs; this export is the CI
    // artifact behind it).
    {
        use pim_gpt::sim::calibrate;
        use pim_gpt::util::json::Json;
        let names = ["gpt2-small", "gpt2-medium", "gpt2-large", "gpt2-xl"];
        let mut rows: Vec<Json> = Vec::new();
        let (mut mean_sum, mut worst) = (0.0f64, 0.0f64);
        println!("sim::profile calibration (seed 7, 6 validation reqs per model):");
        for name in names {
            let model = by_name(name).unwrap();
            let rep = calibrate(&model, &cfg, 7, 6).unwrap();
            println!(
                "  {name:>12}: mean rel err {:.2}%, max {:.2}% over {} validation rows \
                 ({} train reqs)",
                100.0 * rep.mean_rel_err,
                100.0 * rep.max_rel_err,
                rep.rows.len(),
                rep.n_train,
            );
            mean_sum += rep.mean_rel_err;
            worst = worst.max(rep.max_rel_err);
            rows.push(rep.to_json());
        }
        let out = Json::obj(vec![
            ("bench", "calibration".into()),
            ("seed", 7u64.into()),
            ("n_validate", 6u64.into()),
            ("mean_rel_err", (mean_sum / names.len() as f64).into()),
            ("max_rel_err", worst.into()),
            ("models", Json::Arr(rows)),
        ]);
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_calibration.json");
        std::fs::write(path, format!("{out}\n")).expect("write BENCH_calibration.json");
        println!("  wrote {path}");
    }
}
