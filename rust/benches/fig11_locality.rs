//! Bench: regenerate Fig. 11 (row-hit rate ~98%; data-movement reduction
//! 110-259x).
use pim_gpt::report::fig11_locality;
use pim_gpt::util::bench::bench;

fn main() {
    let tokens: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let mut out = None;
    bench("fig11: locality sweep (8 models)", 0, 1, || {
        out = Some(fig11_locality(tokens).unwrap());
    });
    let r = out.unwrap();
    println!("{}\n{}", r.title, r.rendered);
}
