//! Coordinator end-to-end: generation + serving, with the functional
//! artifact when available.

use std::path::Path;

use pim_gpt::config::HwConfig;
use pim_gpt::coordinator::{PimGptSystem, Request, Server};
use pim_gpt::model::gpt::by_name;

fn artifacts_available(name: &str) -> bool {
    Path::new("artifacts").join(format!("{name}.meta.json")).exists()
}

#[test]
fn timing_only_end_to_end() {
    let m = by_name("gpt2-small").unwrap();
    let mut sys = PimGptSystem::timing_only(&m, &HwConfig::paper_baseline()).unwrap();
    let r = sys.generate(&[1, 2, 3, 4], 12).unwrap();
    assert_eq!(r.tokens.len(), 16);
    assert!(r.sim_seconds > 0.0);
    assert!(r.sim_energy_j > 0.0);
}

#[test]
fn functional_end_to_end_with_artifact() {
    if !artifacts_available("gpt-nano") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = HwConfig::paper_baseline();
    let mut sys = PimGptSystem::with_artifact("gpt-nano", Path::new("artifacts"), &cfg).unwrap();
    assert!(sys.has_artifact());
    let r = sys.generate(&[1, 2, 3], 5).unwrap();
    // Functional tokens must match the python golden sequence.
    assert_eq!(r.tokens, vec![1, 2, 3, 295, 295, 295, 295, 295]);
    assert!(r.wall_seconds > 0.0);
    assert!(r.sim_seconds > 0.0);
    // The simulated accelerator must be far faster than functional CPU.
    assert!(r.sim_seconds < r.wall_seconds);
}

#[test]
fn server_handles_mixed_workload() {
    // Responses are matched by id: with the interleaved scheduler an
    // invalid request is rejected at ingestion, so its error response
    // can arrive before earlier requests complete.
    let mut server = Server::start(|| {
        let m = by_name("gpt-nano").unwrap();
        PimGptSystem::timing_only(&m, &HwConfig::paper_baseline())
    });
    // Mix of valid and invalid requests.
    server.submit(Request { id: 0, prompt: vec![1], n_new: 4, arrival_cycle: 0 }).unwrap();
    // id 1 is too long for gpt-nano's max_seq.
    server.submit(Request { id: 1, prompt: vec![0; 200], n_new: 10, arrival_cycle: 0 }).unwrap();
    server.submit(Request { id: 2, prompt: vec![2, 3], n_new: 6, arrival_cycle: 0 }).unwrap();
    let mut by_id = std::collections::BTreeMap::new();
    for _ in 0..3 {
        let r = server.recv().unwrap();
        by_id.insert(r.id, r);
    }
    assert!(by_id[&0].error.is_none() && by_id[&0].tokens.len() == 5);
    assert!(by_id[&1].error.is_some());
    assert!(by_id[&2].error.is_none() && by_id[&2].tokens.len() == 8);
    let m = server.shutdown();
    assert_eq!(m.requests, 3);
    assert_eq!(m.failed, 1);
}

#[test]
fn server_simulated_latency_accumulates_monotonically() {
    // K = 1 pins the scheduler to strict FIFO, where queueing delays
    // accumulate request over request exactly like the seed server.
    let mut server = Server::start(|| {
        let m = by_name("gpt2-small").unwrap();
        PimGptSystem::timing_only(&m, &HwConfig::paper_baseline().with_max_streams(1))
    });
    for id in 0..5 {
        server.submit(Request { id, prompt: vec![1, 2], n_new: 3, arrival_cycle: 0 }).unwrap();
    }
    let mut last_queue = -1.0;
    for _ in 0..5 {
        let r = server.recv().unwrap();
        assert!(r.sim_queue_seconds > last_queue);
        last_queue = r.sim_queue_seconds;
    }
    server.shutdown();
}
