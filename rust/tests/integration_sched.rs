//! Multi-stream scheduler acceptance invariants: K=1 equivalence with
//! the single-stream simulator, deterministic interleaving, and the
//! interleaving throughput win over FIFO.

use pim_gpt::config::HwConfig;
use pim_gpt::model::gpt::by_name;
use pim_gpt::sim::{MultiSim, Simulator, StreamSpec};

/// K=1 scheduling must reproduce the seed simulator's per-token cycle
/// counts exactly — both engines execute through the same
/// `Resources::issue` path, so every (start, finish) pair must match.
#[test]
fn k1_reproduces_single_stream_cycles_exactly() {
    for (model, n_tokens) in [("gpt-nano", 16u64), ("gpt2-small", 12), ("gpt3-xl", 6)] {
        let m = by_name(model).unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(1);

        let mut sim = Simulator::new(&m, &cfg).unwrap();
        let mut want = Vec::new();
        for pos in 0..n_tokens {
            let r = sim.decode_step(pos).unwrap();
            want.push((r.start_cycle, r.finish_cycle));
        }

        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        ms.submit(StreamSpec { id: 0, n_tokens }).unwrap();
        let results = ms.run_all().unwrap();
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.token_finishes.len() as u64, n_tokens, "{model}");
        let mut start = 0u64;
        for (k, &fin) in r.token_finishes.iter().enumerate() {
            assert_eq!(
                (start, fin),
                want[k],
                "{model} token {k}: interleaved K=1 diverged from single-stream"
            );
            start = fin;
        }
        assert_eq!(ms.clock(), sim.clock(), "{model} final clock");
    }
}

/// The K=1 engine must also match across the scores@V chunking regime
/// boundary (gpt2-small: ltoken 85 -> 86), where the cached program
/// template switches.
#[test]
fn k1_equivalence_across_regime_boundary() {
    let m = by_name("gpt2-small").unwrap();
    let cfg = HwConfig::paper_baseline().with_max_streams(1);
    let n_tokens = 90u64;

    let mut sim = Simulator::new(&m, &cfg).unwrap();
    let mut want = Vec::new();
    for pos in 0..n_tokens {
        want.push(sim.decode_step(pos).unwrap().finish_cycle);
    }

    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    ms.submit(StreamSpec { id: 0, n_tokens }).unwrap();
    let r = ms.run_all().unwrap().remove(0);
    assert_eq!(r.token_finishes, want);
}

/// Same request set, same cycle counts — run to run.
#[test]
fn interleaving_is_deterministic() {
    let run = || {
        let m = by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(4);
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for id in 0..6 {
            ms.submit(StreamSpec { id, n_tokens: 2 + id }).unwrap();
        }
        let results = ms.run_all().unwrap();
        ms.finalize_stats();
        let per_req: Vec<(u64, u64, u64)> =
            results.iter().map(|r| (r.id, r.admitted_cycle, r.finish_cycle)).collect();
        (ms.clock(), per_req, ms.stats.instructions)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
}

/// Acceptance: a K=4 mixed-request run delivers strictly higher
/// simulated tokens/s than FIFO (K=1) on the same request set.
#[test]
fn k4_throughput_strictly_beats_fifo() {
    let specs: Vec<StreamSpec> =
        (0..4).map(|id| StreamSpec { id, n_tokens: 4 + 3 * id }).collect();
    let total_tokens: u64 = specs.iter().map(|s| s.n_tokens).sum();
    let run = |k: usize| {
        let m = by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(k);
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for s in &specs {
            ms.submit(*s).unwrap();
        }
        let results = ms.run_all().unwrap();
        let tokens: u64 = results.iter().map(|r| r.tokens).sum();
        assert_eq!(tokens, total_tokens);
        // tokens/s ∝ tokens / makespan cycles; same tokens, so compare
        // makespans directly.
        ms.clock()
    };
    let fifo_makespan = run(1);
    let inter_makespan = run(4);
    assert!(
        inter_makespan < fifo_makespan,
        "K=4 makespan {inter_makespan} !< FIFO {fifo_makespan}"
    );
}

/// Acceptance: a model whose KV reservation cannot fit `max_streams`
/// disjoint contexts degrades to fewer slots (reported, not a panic),
/// and admission then blocks on KV capacity — fewer concurrent streams,
/// `queue_cycles > 0` for the overflow requests, and blocked-admission
/// counters in the stats.
#[test]
fn capacity_limited_model_admits_fewer_streams() {
    let m = by_name("gpt2-small").unwrap();
    let mut cfg = HwConfig::paper_baseline().with_max_streams(4);
    cfg.gddr6.capacity_gbit = 0.34; // ~1392 rows/bank: weights + ~2 contexts
    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    let slots = ms.kv_slots();
    assert!(slots >= 1 && slots < 4, "expected degradation, got {slots} slots");
    let report = ms.mapping.kv_shortfall.as_ref().expect("shortfall must be reported");
    assert_eq!(report.requested, 4);
    assert_eq!(report.granted, slots);

    for id in 0..6 {
        ms.submit(StreamSpec { id, n_tokens: 2 }).unwrap();
    }
    let results = ms.run_all().unwrap();
    ms.finalize_stats();
    assert_eq!(results.len(), 6);
    assert_eq!(ms.stats.kv_slots, slots as u64);
    assert_eq!(ms.stats.peak_slots_in_use, slots as u64);
    assert!(ms.stats.admission_blocked > 0);
    let queued = results.iter().filter(|r| r.queue_cycles() > 0).count();
    assert!(queued >= 6 - slots, "only {queued} of {} overflow requests queued", 6 - slots);
    assert!(results.iter().all(|r| r.kv_slot < slots));
}

/// The degraded-capacity config must not disturb the K=1 equivalence:
/// one slot-partitioned stream still reproduces the single-stream
/// simulator cycle-for-cycle.
#[test]
fn k1_equivalence_holds_under_degraded_capacity() {
    let m = by_name("gpt2-small").unwrap();
    let mut cfg = HwConfig::paper_baseline().with_max_streams(1);
    cfg.gddr6.capacity_gbit = 0.34;
    let n_tokens = 6u64;

    let mut sim = Simulator::new(&m, &cfg).unwrap();
    let mut want = Vec::new();
    for pos in 0..n_tokens {
        want.push(sim.decode_step(pos).unwrap().finish_cycle);
    }

    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    ms.submit(StreamSpec { id: 0, n_tokens }).unwrap();
    let r = ms.run_all().unwrap().remove(0);
    assert_eq!(r.token_finishes, want);
}

/// Multi-stream stats: per-stream attribution sums to the totals, and
/// resource-utilization counters are sane and improve with K.
#[test]
fn utilization_improves_with_interleaving() {
    let run = |k: usize| {
        let m = by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(k);
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for id in 0..4 {
            ms.submit(StreamSpec { id, n_tokens: 6 }).unwrap();
        }
        ms.run_all().unwrap();
        ms.finalize_stats();
        let units = ms.cfg.total_mac_units() as u64;
        (ms.stats.pim_utilization(units), ms.stats.clone())
    };
    let (util1, stats1) = run(1);
    let (util4, stats4) = run(4);
    assert!(util1 > 0.0 && util1 <= 1.0);
    assert!(util4 > util1, "pim util K=4 {util4} !> K=1 {util1}");
    // Identical work, different schedule: same instruction/token totals.
    assert_eq!(stats1.instructions, stats4.instructions);
    assert_eq!(stats1.tokens, stats4.tokens);
    let attr1: u64 = stats1.streams.iter().map(|s| s.attributed_cycles).sum();
    assert!(attr1 > 0);
    assert_eq!(stats1.streams.len(), 4);
    assert_eq!(stats4.streams.len(), 4);
}
