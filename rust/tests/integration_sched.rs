//! Multi-stream scheduler acceptance invariants: K=1 equivalence with
//! the single-stream simulator, deterministic interleaving, the
//! interleaving throughput win over FIFO, open-loop arrival replays
//! (tail-latency percentiles, degraded-capacity interaction), and the
//! scheduling-policy subsystem (SRF / fair-share picking, SLO-aware
//! admission) under Poisson arrivals.

use pim_gpt::config::HwConfig;
use pim_gpt::model::gpt::by_name;
use pim_gpt::sim::arrivals::{self, ArrivalSpec};
use pim_gpt::sim::{MultiSim, Simulator, StreamOutcome, StreamResult, StreamSpec};

/// Keep the completions of a drained run, in completion order.
fn completed(outcomes: Vec<StreamOutcome>) -> Vec<StreamResult> {
    outcomes.into_iter().filter_map(StreamOutcome::into_completed).collect()
}

/// K=1 scheduling must reproduce the seed simulator's per-token cycle
/// counts exactly — both engines execute through the same
/// `Resources::issue` path, so every (start, finish) pair must match.
#[test]
fn k1_reproduces_single_stream_cycles_exactly() {
    for (model, n_tokens) in [("gpt-nano", 16u64), ("gpt2-small", 12), ("gpt3-xl", 6)] {
        let m = by_name(model).unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(1);

        let mut sim = Simulator::new(&m, &cfg).unwrap();
        let mut want = Vec::new();
        for pos in 0..n_tokens {
            let r = sim.decode_step(pos).unwrap();
            want.push((r.start_cycle, r.finish_cycle));
        }

        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        ms.submit(StreamSpec::new(0, n_tokens)).unwrap();
        let results = completed(ms.run_all().unwrap());
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.token_finishes.len() as u64, n_tokens, "{model}");
        let mut start = 0u64;
        for (k, &fin) in r.token_finishes.iter().enumerate() {
            assert_eq!(
                (start, fin),
                want[k],
                "{model} token {k}: interleaved K=1 diverged from single-stream"
            );
            start = fin;
        }
        assert_eq!(ms.clock(), sim.clock(), "{model} final clock");
    }
}

/// The K=1 engine must also match across the scores@V chunking regime
/// boundary (gpt2-small: ltoken 85 -> 86), where the cached program
/// template switches.
#[test]
fn k1_equivalence_across_regime_boundary() {
    let m = by_name("gpt2-small").unwrap();
    let cfg = HwConfig::paper_baseline().with_max_streams(1);
    let n_tokens = 90u64;

    let mut sim = Simulator::new(&m, &cfg).unwrap();
    let mut want = Vec::new();
    for pos in 0..n_tokens {
        want.push(sim.decode_step(pos).unwrap().finish_cycle);
    }

    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    ms.submit(StreamSpec::new(0, n_tokens)).unwrap();
    let r = completed(ms.run_all().unwrap()).remove(0);
    assert_eq!(r.token_finishes, want);
}

/// Same request set, same cycle counts — run to run.
#[test]
fn interleaving_is_deterministic() {
    let run = || {
        let m = by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(4);
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for id in 0..6 {
            ms.submit(StreamSpec::new(id, 2 + id)).unwrap();
        }
        let results = completed(ms.run_all().unwrap());
        ms.finalize_stats();
        let per_req: Vec<(u64, u64, u64)> =
            results.iter().map(|r| (r.id, r.admitted_cycle, r.finish_cycle)).collect();
        (ms.clock(), per_req, ms.stats.instructions)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
}

/// Acceptance: a K=4 mixed-request run delivers strictly higher
/// simulated tokens/s than FIFO (K=1) on the same request set.
#[test]
fn k4_throughput_strictly_beats_fifo() {
    let specs: Vec<StreamSpec> = (0..4).map(|id| StreamSpec::new(id, 4 + 3 * id)).collect();
    let total_tokens: u64 = specs.iter().map(|s| s.n_tokens).sum();
    let run = |k: usize| {
        let m = by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(k);
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for s in &specs {
            ms.submit(*s).unwrap();
        }
        let results = completed(ms.run_all().unwrap());
        let tokens: u64 = results.iter().map(|r| r.tokens).sum();
        assert_eq!(tokens, total_tokens);
        // tokens/s ∝ tokens / makespan cycles; same tokens, so compare
        // makespans directly.
        ms.clock()
    };
    let fifo_makespan = run(1);
    let inter_makespan = run(4);
    assert!(
        inter_makespan < fifo_makespan,
        "K=4 makespan {inter_makespan} !< FIFO {fifo_makespan}"
    );
}

/// Acceptance: a model whose KV reservation cannot fit `max_streams`
/// disjoint contexts degrades to fewer slots (reported, not a panic),
/// and admission then blocks on KV capacity — fewer concurrent streams,
/// `queue_cycles > 0` for the overflow requests, and blocked-admission
/// counters in the stats.
#[test]
fn capacity_limited_model_admits_fewer_streams() {
    let m = by_name("gpt2-small").unwrap();
    let mut cfg = HwConfig::paper_baseline().with_max_streams(4);
    cfg.gddr6.capacity_gbit = 0.34; // ~1392 rows/bank: weights + ~2 contexts
    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    let slots = ms.kv_slots();
    assert!(slots >= 1 && slots < 4, "expected degradation, got {slots} slots");
    let report = ms.mapping.kv_shortfall.as_ref().expect("shortfall must be reported");
    assert_eq!(report.requested, 4);
    assert_eq!(report.granted, slots);

    for id in 0..6 {
        ms.submit(StreamSpec::new(id, 2)).unwrap();
    }
    let results = completed(ms.run_all().unwrap());
    ms.finalize_stats();
    assert_eq!(results.len(), 6);
    assert_eq!(ms.stats.kv_slots, slots as u64);
    assert_eq!(ms.stats.peak_slots_in_use, slots as u64);
    assert!(ms.stats.admission_blocked > 0);
    let queued = results.iter().filter(|r| r.queue_cycles() > 0).count();
    assert!(queued >= 6 - slots, "only {queued} of {} overflow requests queued", 6 - slots);
    assert!(results.iter().all(|r| r.kv_slot < slots));
}

/// The degraded-capacity config must not disturb the K=1 equivalence:
/// one slot-partitioned stream still reproduces the single-stream
/// simulator cycle-for-cycle.
#[test]
fn k1_equivalence_holds_under_degraded_capacity() {
    let m = by_name("gpt2-small").unwrap();
    let mut cfg = HwConfig::paper_baseline().with_max_streams(1);
    cfg.gddr6.capacity_gbit = 0.34;
    let n_tokens = 6u64;

    let mut sim = Simulator::new(&m, &cfg).unwrap();
    let mut want = Vec::new();
    for pos in 0..n_tokens {
        want.push(sim.decode_step(pos).unwrap().finish_cycle);
    }

    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    ms.submit(StreamSpec::new(0, n_tokens)).unwrap();
    let r = completed(ms.run_all().unwrap()).remove(0);
    assert_eq!(r.token_finishes, want);
}

/// Tentpole equivalence pin (chunked prefill): with `prefill_chunk = 1`
/// every prompt position is a 1-position chunk issued with `passes = 1`
/// — cycle-identical to the historical all-decode path. A prompted
/// request under chunk=1 must reproduce the single-stream simulator's
/// per-position finishes exactly, and a 1-token prompt must do so under
/// *any* chunk size (the first chunk is 1 position regardless).
#[test]
fn prefill_chunk_one_reproduces_token_by_token_exactly() {
    let m = by_name("gpt2-small").unwrap();
    let n_tokens = 12u64;
    let mut cfg = HwConfig::paper_baseline().with_max_streams(1);

    let mut sim = Simulator::new(&m, &cfg).unwrap();
    let mut want = Vec::new();
    for pos in 0..n_tokens {
        want.push(sim.decode_step(pos).unwrap().finish_cycle);
    }

    // chunk = 1, multi-token prompt: the prompt/generation split is
    // pure bookkeeping — the schedule is unchanged.
    cfg.sched.prefill_chunk = 1;
    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    ms.submit(StreamSpec::with_prompt(0, 7, n_tokens - 7)).unwrap();
    let r = completed(ms.run_all().unwrap()).remove(0);
    assert_eq!(r.token_finishes, want, "chunk=1 prompted run diverged");
    assert_eq!(r.prompt_tokens, 7);
    // TTFT is now the 7th position's finish — the split changes the
    // *measurement*, never the schedule.
    assert_eq!(r.ttft_cycles(), want[6]);

    // 1-token prompt at the default chunk (32): still identical.
    let cfg = HwConfig::paper_baseline().with_max_streams(1);
    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    ms.submit(StreamSpec::new(0, n_tokens)).unwrap();
    let r = completed(ms.run_all().unwrap()).remove(0);
    assert_eq!(r.token_finishes, want, "1-token prompt diverged at default chunk");
    assert_eq!(r.ttft_cycles(), want[0], "historical TTFT for 1-token prompts");
}

/// Property variant of the chunk=1 equivalence: random prompt splits
/// under `prefill_chunk = 1` always equal the same request with the
/// historical 1-token-prompt split, cycle for cycle (on the same
/// engine-visible schedule — only the TTFT measurement moves).
#[test]
fn prefill_chunk_one_split_invariance_property() {
    use pim_gpt::util::prop::check;
    check("chunk=1 split invariance", 10, |rng| {
        let n_tokens = 2 + rng.gen_range(20);
        let prompt = 1 + rng.gen_range(n_tokens);
        let m = by_name("gpt-nano").unwrap();
        let mut cfg = HwConfig::paper_baseline().with_max_streams(1);
        cfg.sched.prefill_chunk = 1;
        let run = |prompt_tokens: u64| {
            let mut ms = MultiSim::new(&m, &cfg).unwrap();
            ms.submit(StreamSpec { id: 0, n_tokens, prompt_tokens, arrival_cycle: 0 })
                .unwrap();
            completed(ms.run_all().unwrap()).remove(0).token_finishes
        };
        if run(prompt) != run(1) {
            return Err(format!("split {prompt}/{n_tokens} changed the schedule"));
        }
        Ok(())
    });
}

/// Tentpole acceptance pin (satellite): on a 256-token prompt, chunked
/// prefill strictly reduces TTFT versus token-by-token prefill — the
/// weight-row ACT/PRE, GB-staging and ASIC-fill amortization the
/// matrix-matrix chunk programs buy. Monotone across chunk sizes.
#[test]
fn chunked_prefill_reduces_ttft_on_256_token_prompt() {
    let m = by_name("gpt2-small").unwrap();
    let run = |chunk: u64| {
        let mut cfg = HwConfig::paper_baseline().with_max_streams(1);
        cfg.sched.prefill_chunk = chunk;
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        ms.submit(StreamSpec::with_prompt(0, 256, 4)).unwrap();
        let r = completed(ms.run_all().unwrap()).remove(0);
        assert_eq!(r.tokens, 260);
        assert_eq!(r.prompt_tokens, 256);
        (r.ttft_cycles(), r.e2e_cycles())
    };
    let (ttft1, e2e1) = run(1);
    let (ttft32, e2e32) = run(32);
    let (ttft128, e2e128) = run(128);
    assert!(ttft32 < ttft1, "chunk 32 ttft {ttft32} !< token-by-token {ttft1}");
    assert!(ttft128 < ttft32, "chunk 128 ttft {ttft128} !< chunk 32 {ttft32}");
    assert!(e2e32 < e2e1 && e2e128 < e2e32, "makespan follows: {e2e1} {e2e32} {e2e128}");
}

/// Tentpole acceptance: under multi-stream Poisson load, chunked
/// prefill strictly lowers p99 TTFT (and the makespan) versus
/// token-by-token prefill of the same prompted request set — the
/// serving win the subsystem exists for. Seed-deterministic.
#[test]
fn chunked_prefill_lowers_p99_ttft_under_poisson_load() {
    let m = by_name("gpt-nano").unwrap();
    // 6 requests with 64-token prompts arriving ~1k cycles apart on 2
    // slots: prompts dominate service, so prefill speed sets the tail.
    let spec = ArrivalSpec::Poisson { rate_per_s: 1_000_000.0 };
    let at = arrivals::generate(&spec, 6, 1.0, 23).unwrap();
    let run = |chunk: u64| {
        let mut cfg = HwConfig::paper_baseline().with_max_streams(2);
        cfg.sched.prefill_chunk = chunk;
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for (id, &a) in at.iter().enumerate() {
            let mut s = StreamSpec::with_prompt(id as u64, 64, 4);
            s.arrival_cycle = a;
            ms.submit(s).unwrap();
        }
        let n = completed(ms.run_all().unwrap()).len();
        assert_eq!(n, 6);
        ms.finalize_stats();
        (ms.stats.latency_report().unwrap(), ms.clock(), ms.stats.prefill_chunks)
    };
    let (lat1, mk1, chunks1) = run(1);
    let (lat32, mk32, chunks32) = run(32);
    assert_eq!(chunks1, 6 * 64, "token-by-token: one chunk per prompt position");
    assert_eq!(chunks32, 6 * 2, "chunk 32: two chunks per 64-token prompt");
    assert!(
        lat32.ttft.p99 < lat1.ttft.p99,
        "chunked p99 ttft {} !< token-by-token {}",
        lat32.ttft.p99,
        lat1.ttft.p99
    );
    assert!(lat32.ttft.p50 < lat1.ttft.p50, "the median moves too");
    assert!(mk32 < mk1, "chunked makespan {mk32} !< {mk1}");
    // Determinism: same seed, same percentiles.
    assert_eq!(run(32).0, lat32);
}

/// Multi-stream stats: per-stream attribution sums to the totals, and
/// resource-utilization counters are sane and improve with K.
#[test]
fn utilization_improves_with_interleaving() {
    let run = |k: usize| {
        let m = by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(k);
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for id in 0..4 {
            ms.submit(StreamSpec::new(id, 6)).unwrap();
        }
        ms.run_all().unwrap();
        ms.finalize_stats();
        let units = ms.cfg.total_mac_units() as u64;
        (ms.stats.pim_utilization(units), ms.stats.clone())
    };
    let (util1, stats1) = run(1);
    let (util4, stats4) = run(4);
    assert!(util1 > 0.0 && util1 <= 1.0);
    assert!(util4 > util1, "pim util K=4 {util4} !> K=1 {util1}");
    // Identical work, different schedule: same instruction/token totals.
    assert_eq!(stats1.instructions, stats4.instructions);
    assert_eq!(stats1.tokens, stats4.tokens);
    let attr1: u64 = stats1.streams.iter().map(|s| s.attributed_cycles).sum();
    assert!(attr1 > 0);
    assert_eq!(stats1.streams.len(), 4);
    assert_eq!(stats4.streams.len(), 4);
}

/// Tentpole acceptance pin: two requests with arrivals {0, A}, where A
/// is far below the first request's finish, must report `queue_cycles`
/// measured from A — not from the global clock high-water mark (the old
/// `submit` stamped `self.clock`, which zeroed the wait). The
/// batch-at-zero path stays cycle-identical to the pinned K=1
/// equivalence above.
#[test]
fn arrival_stamping_measured_from_arrival_not_clock() {
    let m = by_name("gpt-nano").unwrap();
    let cfg = HwConfig::paper_baseline().with_max_streams(1);
    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    let a = 2_000u64;
    ms.submit(StreamSpec::new(0, 12)).unwrap();
    ms.submit(StreamSpec { id: 1, n_tokens: 2, prompt_tokens: 1, arrival_cycle: a }).unwrap();
    let results = completed(ms.run_all().unwrap());
    let r0 = results.iter().find(|r| r.id == 0).unwrap();
    let r1 = results.iter().find(|r| r.id == 1).unwrap();
    assert!(a < r0.finish_cycle, "A must land mid-batch for the pin to bite");
    assert_eq!(r0.queue_cycles(), 0);
    // The only slot frees at r0's finish; r1 waited from its own arrival.
    assert_eq!(r1.arrival_cycle, a);
    assert_eq!(r1.admitted_cycle, r0.finish_cycle);
    assert_eq!(r1.queue_cycles(), r0.finish_cycle - a);
    assert!(r1.ttft_cycles() > r1.queue_cycles());
}

/// Satellite acceptance: degraded KV capacity x open loop. On the
/// 0.34 Gbit/channel config (2 of 4 requested slots), an overloaded
/// Poisson replay must show positive p99 queueing with every granted
/// slot in use — and identical seeds must reproduce identical
/// percentiles (no wall clock or OS RNG anywhere in the sim).
#[test]
fn degraded_capacity_open_loop_poisson_tail() {
    let m = by_name("gpt2-small").unwrap();
    let mut cfg = HwConfig::paper_baseline().with_max_streams(4);
    cfg.gddr6.capacity_gbit = 0.34;
    // ~1e6 req/s at 1 GHz = one arrival per ~1000 cycles, far faster
    // than a 2-token gpt2-small service: a guaranteed overload.
    let spec = ArrivalSpec::Poisson { rate_per_s: 1_000_000.0 };
    let run = |seed: u64| {
        let at = arrivals::generate(&spec, 8, cfg.gddr6.freq_ghz, seed).unwrap();
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for (id, &arrival_cycle) in at.iter().enumerate() {
            let id = id as u64;
            ms.submit(StreamSpec { id, n_tokens: 2, prompt_tokens: 1, arrival_cycle }).unwrap();
        }
        let n = completed(ms.run_all().unwrap()).len();
        ms.finalize_stats();
        assert_eq!(n, 8);
        (ms.kv_slots(), ms.stats.clone())
    };
    let (slots, stats) = run(7);
    assert!(slots < 4, "expected degraded capacity, got {slots} slots");
    assert_eq!(stats.peak_slots_in_use, slots as u64);
    assert!(stats.admission_blocked > 0);
    let lat = stats.latency_report().unwrap();
    assert!(lat.queue.p99 > 0, "overloaded run must show tail queueing");
    assert!(lat.ttft.p99 >= lat.queue.p99, "ttft includes the queue wait");
    assert!(lat.e2e.p99 >= lat.ttft.p99);
    // Determinism: same seed, same percentiles; the arrival trace
    // itself shifts with the seed.
    let (_, stats_again) = run(7);
    assert_eq!(stats_again.latency_report().unwrap(), lat);
    let a7 = arrivals::generate(&spec, 8, 1.0, 7).unwrap();
    let a8 = arrivals::generate(&spec, 8, 1.0, 8).unwrap();
    assert_ne!(a7, a8);
}

/// Open loop on the healthy config: a fixed-interval replay paced
/// slower than the service rate shows zero queueing (every request
/// admitted at its own arrival), while the same set compressed to
/// batch-at-zero queues on slot capacity — the generators and the
/// admission path agree end-to-end.
#[test]
fn fixed_interval_pacing_vs_batch_compression() {
    let m = by_name("gpt-nano").unwrap();
    let cfg = HwConfig::paper_baseline().with_max_streams(2);
    // Measure one request's service time to pace the open-loop run.
    let mut probe = MultiSim::new(&m, &cfg).unwrap();
    probe.submit(StreamSpec::new(0, 2)).unwrap();
    let service = completed(probe.run_all().unwrap())[0].service_cycles();

    let interval = 2 * service; // slower than service on 2 slots
    let spec = ArrivalSpec::Fixed { interval_cycles: interval };
    let at = arrivals::generate(&spec, 6, cfg.gddr6.freq_ghz, 0).unwrap();
    let mut paced = MultiSim::new(&m, &cfg).unwrap();
    let mut batch = MultiSim::new(&m, &cfg).unwrap();
    for (id, &arrival_cycle) in at.iter().enumerate() {
        let id = id as u64;
        paced.submit(StreamSpec { id, n_tokens: 2, prompt_tokens: 1, arrival_cycle }).unwrap();
        batch.submit(StreamSpec::new(id, 2)).unwrap();
    }
    let paced_results = completed(paced.run_all().unwrap());
    let batch_results = completed(batch.run_all().unwrap());
    for r in &paced_results {
        assert_eq!(r.queue_cycles(), 0, "request {} queued under slack pacing", r.id);
        assert_eq!(r.admitted_cycle, r.arrival_cycle);
    }
    let queued = batch_results.iter().filter(|r| r.queue_cycles() > 0).count();
    assert!(queued >= 4, "6 batch requests on 2 slots: {queued} queued");
}

fn policy_cfg(k: usize, policy: &str) -> HwConfig {
    let mut cfg = HwConfig::paper_baseline().with_max_streams(k);
    cfg.sched.set_policy_str(policy).unwrap();
    cfg
}

/// Shared SLO calibration: probe the isolated first-token cost (a
/// fresh engine's wait-free request: its first token *is* the isolated
/// regime-0 replay) and place the TTFT budget a few multiples above it
/// — generous enough to admit wait-free requests past the engine's
/// conservative warm-start padding, far below an overloaded queue wait.
fn slo_probe_budget(m: &pim_gpt::model::GptModel) -> u64 {
    let mut probe = MultiSim::new(m, &policy_cfg(1, "fcfs")).unwrap();
    probe.submit(StreamSpec::new(0, 2)).unwrap();
    let ttft0 = completed(probe.run_all().unwrap())[0].token_finishes[0];
    assert!(ttft0 > 0);
    4 * ttft0 + 3_000
}

/// Tentpole acceptance (satellite pin): shortest-remaining-first beats
/// FCFS on mean end-to-end latency for one long + many short streams
/// under Poisson arrivals. The long request arrives first and is
/// admitted; the rest (one medium + four shorts) arrive during its
/// service, so the first retirement finds a heterogeneous queue — SRF
/// drains the shorts before the medium, FCFS the reverse, and serving
/// shorter work first strictly lowers the completion-time sum (SPT
/// optimality). Seed-deterministic: the same seed replays the same
/// trace and the same means.
#[test]
fn srf_beats_fcfs_on_mean_e2e_with_one_long_many_short() {
    let m = by_name("gpt-nano").unwrap();
    let lens = [16u64, 12, 2, 2, 2, 2];
    // Mean inter-arrival 250 cycles at 1 GHz: all six requests arrive
    // orders of magnitude before the 16-token head-of-line finishes.
    let spec = ArrivalSpec::Poisson { rate_per_s: 4_000_000.0 };
    let at = arrivals::generate(&spec, lens.len(), 1.0, 11).unwrap();
    let run = |policy: &str| -> f64 {
        let mut ms = MultiSim::new(&m, &policy_cfg(1, policy)).unwrap();
        for (id, (&n, &a)) in lens.iter().zip(at.iter()).enumerate() {
            ms.submit(StreamSpec { id: id as u64, n_tokens: n, prompt_tokens: 1, arrival_cycle: a })
                .unwrap();
        }
        let results = completed(ms.run_all().unwrap());
        assert_eq!(results.len(), lens.len(), "admit-always completes everything");
        results.iter().map(|r| r.e2e_cycles() as f64).sum::<f64>() / lens.len() as f64
    };
    let fcfs = run("fcfs");
    let srf = run("srf");
    assert!(srf < fcfs, "srf mean e2e {srf} !< fcfs {fcfs}");
    assert_eq!(run("srf").to_bits(), srf.to_bits(), "identical seed, identical mean");
}

/// Tentpole acceptance: fair-share bounds the spread of per-stream
/// service cycles for identical-length streams under Poisson arrivals —
/// every stream stays within half the slowest stream's service of each
/// other — and identical seeds reproduce identical spreads.
#[test]
fn fair_share_bounds_spread_under_poisson() {
    let m = by_name("gpt-nano").unwrap();
    let spec = ArrivalSpec::Poisson { rate_per_s: 4_000_000.0 };
    let at = arrivals::generate(&spec, 4, 1.0, 13).unwrap();
    let run = || {
        let mut ms = MultiSim::new(&m, &policy_cfg(4, "fair")).unwrap();
        for (id, &a) in at.iter().enumerate() {
            ms.submit(StreamSpec { id: id as u64, n_tokens: 6, prompt_tokens: 1, arrival_cycle: a })
                .unwrap();
        }
        let results = completed(ms.run_all().unwrap());
        assert_eq!(results.len(), 4);
        results.iter().map(|r| r.service_cycles()).collect::<Vec<_>>()
    };
    let services = run();
    let max = *services.iter().max().unwrap();
    let min = *services.iter().min().unwrap();
    assert!(min > 0);
    assert!(
        max - min <= max / 2,
        "fair-share spread {} exceeds half the max service {max}",
        max - min
    );
    assert_eq!(run(), services, "identical seed, identical services");
}

/// Tentpole acceptance: SLO-aware admission under Poisson overload on
/// one slot sheds load (`rejected > 0`) while every *admitted* request
/// keeps its measured TTFT within the budget — the policy admits only
/// when `wait + conservative-first-token-estimate <= budget`, and at
/// effective K = 1 the estimate upper-bounds the realized first-token
/// service. Seed-deterministic end to end.
#[test]
fn slo_admission_keeps_p99_ttft_under_budget_and_sheds_overload() {
    let m = by_name("gpt-nano").unwrap();
    let budget = slo_probe_budget(&m);

    // Offered load: one 8-token request per ~1000 cycles on a single
    // slot whose 8-token service costs ~8x the first token — a massive
    // overload, so queue waits blow past the budget quickly.
    let spec = ArrivalSpec::Poisson { rate_per_s: 1_000_000.0 };
    let at = arrivals::generate(&spec, 12, 1.0, 17).unwrap();
    let run = || {
        let mut ms = MultiSim::new(&m, &policy_cfg(1, &format!("slo:{budget}"))).unwrap();
        for (id, &a) in at.iter().enumerate() {
            ms.submit(StreamSpec { id: id as u64, n_tokens: 8, prompt_tokens: 1, arrival_cycle: a })
                .unwrap();
        }
        let outcomes = ms.run_all().unwrap();
        ms.finalize_stats();
        assert_eq!(outcomes.len(), 12, "every request reaches a terminal outcome");
        let served: Vec<u64> =
            outcomes.iter().filter_map(|o| o.as_completed().map(|r| r.id)).collect();
        let shed: Vec<u64> =
            outcomes.iter().filter_map(|o| o.as_rejected().map(|r| r.id)).collect();
        assert_eq!(ms.stats.rejected as usize, shed.len());
        (served, shed, ms.stats.latency_report())
    };
    let (served, shed, lat) = run();
    assert!(!served.is_empty(), "the wait-free head of line must be admitted");
    assert!(!shed.is_empty(), "overload past the budget must shed requests");
    assert_eq!(served.len() + shed.len(), 12);
    let lat = lat.expect("admitted streams leave percentiles");
    assert!(
        lat.ttft.max <= budget,
        "admitted TTFT max {} busts the budget {budget}",
        lat.ttft.max
    );
    assert!(lat.ttft.p99 <= budget, "p99 {} busts the budget {budget}", lat.ttft.p99);
    // Determinism: the same seed reproduces the same admit/shed split
    // and the same percentiles.
    assert_eq!(run(), (served, shed, Some(lat)));
}

/// SLO admission composes with real concurrency: under K=4 Poisson
/// overload it still sheds deterministically, completions plus
/// rejections account for every request, and rejections carry the
/// busted prediction.
#[test]
fn slo_admission_under_concurrency_is_deterministic() {
    let m = by_name("gpt-nano").unwrap();
    let budget = slo_probe_budget(&m);
    let spec = ArrivalSpec::Poisson { rate_per_s: 4_000_000.0 };
    let at = arrivals::generate(&spec, 16, 1.0, 19).unwrap();
    let run = || {
        let mut ms = MultiSim::new(&m, &policy_cfg(4, &format!("slo:{budget}"))).unwrap();
        for (id, &a) in at.iter().enumerate() {
            ms.submit(StreamSpec { id: id as u64, n_tokens: 8, prompt_tokens: 1, arrival_cycle: a })
                .unwrap();
        }
        let outcomes = ms.run_all().unwrap();
        ms.finalize_stats();
        let sig: Vec<(u64, bool, u64)> = outcomes
            .iter()
            .map(|o| match o {
                StreamOutcome::Completed(r) => (r.id, false, r.finish_cycle),
                StreamOutcome::Rejected(r) => (r.id, true, r.decided_cycle),
            })
            .collect();
        (sig, ms.stats.rejected)
    };
    let (sig, rejected) = run();
    assert_eq!(sig.len(), 16);
    assert!(rejected > 0, "16 8-token requests in ~4k cycles on 4 slots must shed");
    assert!(rejected < 16, "the first arrivals are wait-free and must be admitted");
    assert_eq!(run(), (sig, rejected), "identical seed, identical outcome sequence");
}

/// Tentpole equivalence pin (batched decode): with `batch_decode = on`
/// but only one KV slot, a fused batch can never form (fusion needs two
/// co-resident decode streams), so the run must stay cycle-identical to
/// the pinned single-stream equivalence above — and to the same run
/// with batching off.
#[test]
fn batch_decode_on_at_k1_reproduces_single_stream_cycles_exactly() {
    let m = by_name("gpt-nano").unwrap();
    let n_tokens = 12u64;
    let base = HwConfig::paper_baseline().with_max_streams(1);

    let mut sim = Simulator::new(&m, &base).unwrap();
    let mut want = Vec::new();
    for pos in 0..n_tokens {
        want.push(sim.decode_step(pos).unwrap().finish_cycle);
    }

    let run = |batch: bool| {
        let cfg = base.clone().with_batch_decode(batch);
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        ms.submit(StreamSpec::new(0, n_tokens)).unwrap();
        let r = completed(ms.run_all().unwrap()).remove(0);
        ms.finalize_stats();
        assert_eq!(ms.stats.fused_sweeps, 0, "K=1 must never fuse");
        (r.token_finishes, ms.clock())
    };
    let (on_fin, on_clock) = run(true);
    let (off_fin, off_clock) = run(false);
    assert_eq!(on_fin, want, "batch_decode=on at K=1 diverged from single-stream");
    assert_eq!(on_fin, off_fin);
    assert_eq!(on_clock, off_clock);
    assert_eq!(on_clock, sim.clock());
}

/// Tentpole acceptance: at saturation (K identical streams, batch at
/// zero), batched decode strictly beats the unbatched schedule on
/// busy-cycle tokens/s, and the win *grows* with K — the ACT/PRE and
/// ASIC-fill amortization is shared by more streams per sweep.
#[test]
fn saturated_batched_decode_beats_unbatched_and_scales_with_k() {
    let m = by_name("gpt-nano").unwrap();
    let run = |k: usize, batch: bool| {
        let cfg = HwConfig::paper_baseline().with_max_streams(k).with_batch_decode(batch);
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for id in 0..4u64 {
            ms.submit(StreamSpec::new(id, 16)).unwrap();
        }
        let results = completed(ms.run_all().unwrap());
        assert_eq!(results.len(), 4);
        ms.finalize_stats();
        let tokens: u64 = results.iter().map(|r| r.tokens).sum();
        assert_eq!(tokens, 64);
        // Batch-at-zero: no idle warp time, so busy == makespan cycles.
        assert_eq!(ms.stats.busy_cycles(), ms.clock());
        let tput = tokens as f64 / ms.stats.busy_cycles() as f64;
        (tput, ms.stats.clone())
    };
    let (off2, _) = run(2, false);
    let (on2, stats2) = run(2, true);
    let (off4, _) = run(4, false);
    let (on4, stats4) = run(4, true);
    assert!(on2 > off2, "K=2 batched tok/cycle {on2} !> unbatched {off2}");
    assert!(on4 > off4, "K=4 batched tok/cycle {on4} !> unbatched {off4}");
    assert!(
        on4 / off4 > on2 / off2,
        "speedup must grow with K: K=4 {} !> K=2 {}",
        on4 / off4,
        on2 / off2
    );
    assert!(stats2.fused_sweeps > 0 && stats4.fused_sweeps > 0);
    assert!(stats4.mean_decode_batch() > stats2.mean_decode_batch());
    assert_eq!(stats4.max_decode_batch, 4, "saturated K=4 must reach full-width sweeps");
}

/// Batched decode under an overloaded Poisson trace with mixed request
/// lengths: every request completes, token totals match the unbatched
/// run (batching changes the schedule, never the work), fusion engages,
/// and the same seed replays the same cycle-exact outcome sequence.
#[test]
fn batched_poisson_trace_conserves_tokens_and_is_deterministic() {
    let m = by_name("gpt-nano").unwrap();
    let lens = [2u64, 6, 10, 4, 8, 3, 5, 7];
    let spec = ArrivalSpec::Poisson { rate_per_s: 2_000_000.0 };
    let at = arrivals::generate(&spec, lens.len(), 1.0, 29).unwrap();
    let run = |batch: bool| {
        let cfg = HwConfig::paper_baseline().with_max_streams(4).with_batch_decode(batch);
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for (id, (&n, &a)) in lens.iter().zip(at.iter()).enumerate() {
            ms.submit(StreamSpec { id: id as u64, n_tokens: n, prompt_tokens: 1, arrival_cycle: a })
                .unwrap();
        }
        let results = completed(ms.run_all().unwrap());
        assert_eq!(results.len(), lens.len());
        ms.finalize_stats();
        let sig: Vec<(u64, u64, u64, Vec<u64>)> = results
            .iter()
            .map(|r| (r.id, r.admitted_cycle, r.finish_cycle, r.token_finishes.clone()))
            .collect();
        let tokens: u64 = results.iter().map(|r| r.tokens).sum();
        (sig, tokens, ms.stats.clone())
    };
    let (sig_on, tokens_on, stats_on) = run(true);
    let (_, tokens_off, stats_off) = run(false);
    assert_eq!(tokens_on, lens.iter().sum::<u64>());
    assert_eq!(tokens_on, tokens_off, "batching must not change the delivered work");
    // A fused shareable node issues once for the whole batch, so the
    // engine executes strictly fewer instructions; each stream still
    // accounts a full program (the per-stream sum is conserved).
    assert!(stats_on.instructions < stats_off.instructions);
    let per_stream = |s: &pim_gpt::sim::SimStats| -> u64 {
        s.streams.iter().map(|st| st.instructions).sum()
    };
    assert_eq!(per_stream(&stats_on), per_stream(&stats_off));
    assert!(stats_on.fused_sweeps > 0, "overloaded 4-slot trace must fuse");
    assert_eq!(stats_off.fused_sweeps, 0);
    assert_eq!(run(true).0, sig_on, "identical seed, identical cycle-exact schedule");
}

/// Tentpole equivalence pin (paged KV): paging with one full-context
/// page per stream (`kv_page_tokens = max_seq`) and no
/// oversubscription must be cycle-identical to the slot engine on a
/// prompted open-loop trace that crosses the scores@V regime boundary
/// — admission stamps, per-token finishes and the final clock all
/// match (slot ids are excluded: paged slots are virtual).
#[test]
fn paged_full_context_matches_slot_engine_on_prompted_trace() {
    let m = by_name("gpt2-small").unwrap();
    let reqs: [(u64, u64, u64); 5] =
        [(0, 8, 90), (1, 64, 30), (2, 1, 12), (3, 32, 64), (4, 8, 8)];
    let run = |paged: bool| {
        let mut cfg = HwConfig::paper_baseline().with_max_streams(3);
        if paged {
            cfg.sched.kv_paging = true;
            cfg.sched.kv_page_tokens = m.max_seq as u64; // 1 frame per context
        }
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for (i, &(arrival, prompt, gen)) in reqs.iter().enumerate() {
            let mut s = StreamSpec::with_prompt(i as u64, prompt, gen);
            s.arrival_cycle = arrival * 50_000;
            ms.submit(s).unwrap();
        }
        let mut rows: Vec<(u64, u64, u64, Vec<u64>)> = completed(ms.run_all().unwrap())
            .into_iter()
            .map(|r| (r.id, r.admitted_cycle, r.finish_cycle, r.token_finishes))
            .collect();
        rows.sort_by_key(|r| r.0);
        ms.finalize_stats();
        (ms.clock(), ms.stats.instructions, rows)
    };
    let slot = run(false);
    let paged = run(true);
    assert_eq!(slot, paged, "paged full-context engine diverged from slot engine");
}

/// Tentpole acceptance: on gpt2-xl at the Table I baseline the slot
/// engine grants only 2 whole-context slots, but the paged engine's
/// frame-granular grant sustains >= 3 concurrent short-prompt streams
/// with zero queueing — the headline capacity win of page-table
/// indirection.
#[test]
fn paged_gpt2_xl_sustains_three_short_streams_at_baseline() {
    let m = by_name("gpt2-xl").unwrap();
    let run = |paged: bool| {
        let mut cfg = HwConfig::paper_baseline().with_max_streams(4);
        if paged {
            cfg.sched.kv_paging = true;
            cfg.sched.kv_page_tokens = 128;
        }
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for id in 0..3 {
            ms.submit(StreamSpec::with_prompt(id, 8, 8)).unwrap();
        }
        let results = completed(ms.run_all().unwrap());
        assert_eq!(results.len(), 3);
        ms.finalize_stats();
        (results, ms.stats.clone())
    };
    let (slot_results, slot_stats) = run(false);
    assert!(
        slot_stats.kv_slots < 3,
        "baseline gpt2-xl should grant < 3 whole-context slots, got {}",
        slot_stats.kv_slots
    );
    assert!(slot_results.iter().any(|r| r.queue_cycles() > 0), "third stream must queue");

    let (paged_results, paged_stats) = run(true);
    assert!(paged_stats.kv_pages >= 3, "frame grant {} too small", paged_stats.kv_pages);
    assert_eq!(paged_stats.peak_slots_in_use, 3, "all three streams co-resident");
    for r in &paged_results {
        assert_eq!(r.queue_cycles(), 0, "stream {} queued under paging", r.id);
        assert_eq!(r.admitted_cycle, 0);
        assert_eq!(r.tokens, 16);
    }
    assert_eq!((paged_stats.page_faults, paged_stats.preemptions), (0, 0));
    // A 16-token stream never outgrows its first 128-token frame, and
    // the slot engine serializes the third stream: paging finishes first.
    let mk = |rs: &[StreamResult]| rs.iter().map(|r| r.finish_cycle).max().unwrap();
    assert!(mk(&paged_results) < mk(&slot_results));
    // Full-length requests exceed the degraded frame pool and are
    // rejected at submit (eviction could never make room for them).
    let mut cfg = HwConfig::paper_baseline().with_max_streams(4);
    cfg.sched.kv_paging = true;
    cfg.sched.kv_page_tokens = 128;
    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    let err = ms.submit(StreamSpec::new(9, m.max_seq as u64)).unwrap_err();
    assert!(err.to_string().contains("frame"), "{err}");
}

/// Oversubscribed paged serving end to end: an over-committed frame
/// pool faults, preempts and re-admits, yet the counters reconcile —
/// submitted = completed + rejected, every stream delivers its exact
/// token count, no stream is left swapped out, and every frame returns
/// to the free list.
#[test]
fn oversubscribed_paging_reconciles_counters_end_to_end() {
    let m = by_name("gpt2-small").unwrap();
    let mut cfg = HwConfig::paper_baseline().with_max_streams(4);
    cfg.gddr6.capacity_gbit = 0.34; // weights + ~2 whole contexts of rows
    cfg.sched.kv_paging = true;
    cfg.sched.kv_page_tokens = 128;
    cfg.sched.kv_oversub = 2.0;
    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    let pool = ms.kv_pages() as u64;
    // Each stream eventually needs 6 frames (768 tokens at P=128); four
    // of them over-commit the ~16-frame pool, forcing faults.
    assert!(pool < 24, "pool {pool} too large to oversubscribe");
    for id in 0..4 {
        ms.submit(StreamSpec::with_prompt(id, 704, 64)).unwrap();
    }
    let results = completed(ms.run_all().unwrap());
    ms.finalize_stats();
    let s = &ms.stats;
    assert_eq!(results.len(), 4, "every admitted stream eventually completes");
    for r in &results {
        assert_eq!(r.tokens, 768);
        assert_eq!(r.token_finishes.len(), 768);
        assert!(r.token_finishes.windows(2).all(|w| w[0] <= w[1]));
    }
    // submitted = completed + rejected; nothing in flight, nothing
    // swapped out, every frame back on the free list.
    assert_eq!(s.streams.len() as u64 + s.rejected, 4);
    assert_eq!(s.rejected, 0);
    assert_eq!(ms.active_streams(), 0);
    assert_eq!(ms.queued_streams(), 0);
    assert_eq!(ms.evicted_streams(), 0);
    assert_eq!(ms.free_kv_pages() as u64, pool);
    assert!(s.page_faults >= 1, "over-committed pool must fault");
    assert!(s.preemptions >= 1);
    assert!(s.evicted_tokens >= 1);
    assert!(s.peak_pages_in_use <= pool);
    assert_eq!(s.kv_pages, pool);
}

/// With the default `fcfs` policy the engine never rejects and the
/// stats stay rejection-free — the policy subsystem is invisible unless
/// asked for (guards the cycle-identity contract from the stats side).
#[test]
fn default_policy_never_rejects() {
    let m = by_name("gpt-nano").unwrap();
    let mut ms = MultiSim::new(&m, &HwConfig::paper_baseline()).unwrap();
    for id in 0..6 {
        ms.submit(StreamSpec { id, n_tokens: 3, prompt_tokens: 1, arrival_cycle: id * 400 })
            .unwrap();
    }
    let outcomes = ms.run_all().unwrap();
    ms.finalize_stats();
    assert_eq!(completed(outcomes).len(), 6);
    assert_eq!(ms.stats.rejected, 0);
    assert_eq!(ms.undelivered_rejections(), 0);
}
