//! Multi-stream scheduler acceptance invariants: K=1 equivalence with
//! the single-stream simulator, deterministic interleaving, the
//! interleaving throughput win over FIFO, and open-loop arrival
//! replays (tail-latency percentiles, degraded-capacity interaction).

use pim_gpt::config::HwConfig;
use pim_gpt::model::gpt::by_name;
use pim_gpt::sim::arrivals::{self, ArrivalSpec};
use pim_gpt::sim::{MultiSim, Simulator, StreamSpec};

/// K=1 scheduling must reproduce the seed simulator's per-token cycle
/// counts exactly — both engines execute through the same
/// `Resources::issue` path, so every (start, finish) pair must match.
#[test]
fn k1_reproduces_single_stream_cycles_exactly() {
    for (model, n_tokens) in [("gpt-nano", 16u64), ("gpt2-small", 12), ("gpt3-xl", 6)] {
        let m = by_name(model).unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(1);

        let mut sim = Simulator::new(&m, &cfg).unwrap();
        let mut want = Vec::new();
        for pos in 0..n_tokens {
            let r = sim.decode_step(pos).unwrap();
            want.push((r.start_cycle, r.finish_cycle));
        }

        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        ms.submit(StreamSpec::new(0, n_tokens)).unwrap();
        let results = ms.run_all().unwrap();
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.token_finishes.len() as u64, n_tokens, "{model}");
        let mut start = 0u64;
        for (k, &fin) in r.token_finishes.iter().enumerate() {
            assert_eq!(
                (start, fin),
                want[k],
                "{model} token {k}: interleaved K=1 diverged from single-stream"
            );
            start = fin;
        }
        assert_eq!(ms.clock(), sim.clock(), "{model} final clock");
    }
}

/// The K=1 engine must also match across the scores@V chunking regime
/// boundary (gpt2-small: ltoken 85 -> 86), where the cached program
/// template switches.
#[test]
fn k1_equivalence_across_regime_boundary() {
    let m = by_name("gpt2-small").unwrap();
    let cfg = HwConfig::paper_baseline().with_max_streams(1);
    let n_tokens = 90u64;

    let mut sim = Simulator::new(&m, &cfg).unwrap();
    let mut want = Vec::new();
    for pos in 0..n_tokens {
        want.push(sim.decode_step(pos).unwrap().finish_cycle);
    }

    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    ms.submit(StreamSpec::new(0, n_tokens)).unwrap();
    let r = ms.run_all().unwrap().remove(0);
    assert_eq!(r.token_finishes, want);
}

/// Same request set, same cycle counts — run to run.
#[test]
fn interleaving_is_deterministic() {
    let run = || {
        let m = by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(4);
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for id in 0..6 {
            ms.submit(StreamSpec::new(id, 2 + id)).unwrap();
        }
        let results = ms.run_all().unwrap();
        ms.finalize_stats();
        let per_req: Vec<(u64, u64, u64)> =
            results.iter().map(|r| (r.id, r.admitted_cycle, r.finish_cycle)).collect();
        (ms.clock(), per_req, ms.stats.instructions)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
}

/// Acceptance: a K=4 mixed-request run delivers strictly higher
/// simulated tokens/s than FIFO (K=1) on the same request set.
#[test]
fn k4_throughput_strictly_beats_fifo() {
    let specs: Vec<StreamSpec> = (0..4).map(|id| StreamSpec::new(id, 4 + 3 * id)).collect();
    let total_tokens: u64 = specs.iter().map(|s| s.n_tokens).sum();
    let run = |k: usize| {
        let m = by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(k);
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for s in &specs {
            ms.submit(*s).unwrap();
        }
        let results = ms.run_all().unwrap();
        let tokens: u64 = results.iter().map(|r| r.tokens).sum();
        assert_eq!(tokens, total_tokens);
        // tokens/s ∝ tokens / makespan cycles; same tokens, so compare
        // makespans directly.
        ms.clock()
    };
    let fifo_makespan = run(1);
    let inter_makespan = run(4);
    assert!(
        inter_makespan < fifo_makespan,
        "K=4 makespan {inter_makespan} !< FIFO {fifo_makespan}"
    );
}

/// Acceptance: a model whose KV reservation cannot fit `max_streams`
/// disjoint contexts degrades to fewer slots (reported, not a panic),
/// and admission then blocks on KV capacity — fewer concurrent streams,
/// `queue_cycles > 0` for the overflow requests, and blocked-admission
/// counters in the stats.
#[test]
fn capacity_limited_model_admits_fewer_streams() {
    let m = by_name("gpt2-small").unwrap();
    let mut cfg = HwConfig::paper_baseline().with_max_streams(4);
    cfg.gddr6.capacity_gbit = 0.34; // ~1392 rows/bank: weights + ~2 contexts
    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    let slots = ms.kv_slots();
    assert!(slots >= 1 && slots < 4, "expected degradation, got {slots} slots");
    let report = ms.mapping.kv_shortfall.as_ref().expect("shortfall must be reported");
    assert_eq!(report.requested, 4);
    assert_eq!(report.granted, slots);

    for id in 0..6 {
        ms.submit(StreamSpec::new(id, 2)).unwrap();
    }
    let results = ms.run_all().unwrap();
    ms.finalize_stats();
    assert_eq!(results.len(), 6);
    assert_eq!(ms.stats.kv_slots, slots as u64);
    assert_eq!(ms.stats.peak_slots_in_use, slots as u64);
    assert!(ms.stats.admission_blocked > 0);
    let queued = results.iter().filter(|r| r.queue_cycles() > 0).count();
    assert!(queued >= 6 - slots, "only {queued} of {} overflow requests queued", 6 - slots);
    assert!(results.iter().all(|r| r.kv_slot < slots));
}

/// The degraded-capacity config must not disturb the K=1 equivalence:
/// one slot-partitioned stream still reproduces the single-stream
/// simulator cycle-for-cycle.
#[test]
fn k1_equivalence_holds_under_degraded_capacity() {
    let m = by_name("gpt2-small").unwrap();
    let mut cfg = HwConfig::paper_baseline().with_max_streams(1);
    cfg.gddr6.capacity_gbit = 0.34;
    let n_tokens = 6u64;

    let mut sim = Simulator::new(&m, &cfg).unwrap();
    let mut want = Vec::new();
    for pos in 0..n_tokens {
        want.push(sim.decode_step(pos).unwrap().finish_cycle);
    }

    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    ms.submit(StreamSpec::new(0, n_tokens)).unwrap();
    let r = ms.run_all().unwrap().remove(0);
    assert_eq!(r.token_finishes, want);
}

/// Multi-stream stats: per-stream attribution sums to the totals, and
/// resource-utilization counters are sane and improve with K.
#[test]
fn utilization_improves_with_interleaving() {
    let run = |k: usize| {
        let m = by_name("gpt2-small").unwrap();
        let cfg = HwConfig::paper_baseline().with_max_streams(k);
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for id in 0..4 {
            ms.submit(StreamSpec::new(id, 6)).unwrap();
        }
        ms.run_all().unwrap();
        ms.finalize_stats();
        let units = ms.cfg.total_mac_units() as u64;
        (ms.stats.pim_utilization(units), ms.stats.clone())
    };
    let (util1, stats1) = run(1);
    let (util4, stats4) = run(4);
    assert!(util1 > 0.0 && util1 <= 1.0);
    assert!(util4 > util1, "pim util K=4 {util4} !> K=1 {util1}");
    // Identical work, different schedule: same instruction/token totals.
    assert_eq!(stats1.instructions, stats4.instructions);
    assert_eq!(stats1.tokens, stats4.tokens);
    let attr1: u64 = stats1.streams.iter().map(|s| s.attributed_cycles).sum();
    assert!(attr1 > 0);
    assert_eq!(stats1.streams.len(), 4);
    assert_eq!(stats4.streams.len(), 4);
}

/// Tentpole acceptance pin: two requests with arrivals {0, A}, where A
/// is far below the first request's finish, must report `queue_cycles`
/// measured from A — not from the global clock high-water mark (the old
/// `submit` stamped `self.clock`, which zeroed the wait). The
/// batch-at-zero path stays cycle-identical to the pinned K=1
/// equivalence above.
#[test]
fn arrival_stamping_measured_from_arrival_not_clock() {
    let m = by_name("gpt-nano").unwrap();
    let cfg = HwConfig::paper_baseline().with_max_streams(1);
    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    let a = 2_000u64;
    ms.submit(StreamSpec::new(0, 12)).unwrap();
    ms.submit(StreamSpec { id: 1, n_tokens: 2, arrival_cycle: a }).unwrap();
    let results = ms.run_all().unwrap();
    let r0 = results.iter().find(|r| r.id == 0).unwrap();
    let r1 = results.iter().find(|r| r.id == 1).unwrap();
    assert!(a < r0.finish_cycle, "A must land mid-batch for the pin to bite");
    assert_eq!(r0.queue_cycles(), 0);
    // The only slot frees at r0's finish; r1 waited from its own arrival.
    assert_eq!(r1.arrival_cycle, a);
    assert_eq!(r1.admitted_cycle, r0.finish_cycle);
    assert_eq!(r1.queue_cycles(), r0.finish_cycle - a);
    assert!(r1.ttft_cycles() > r1.queue_cycles());
}

/// Satellite acceptance: degraded KV capacity x open loop. On the
/// 0.34 Gbit/channel config (2 of 4 requested slots), an overloaded
/// Poisson replay must show positive p99 queueing with every granted
/// slot in use — and identical seeds must reproduce identical
/// percentiles (no wall clock or OS RNG anywhere in the sim).
#[test]
fn degraded_capacity_open_loop_poisson_tail() {
    let m = by_name("gpt2-small").unwrap();
    let mut cfg = HwConfig::paper_baseline().with_max_streams(4);
    cfg.gddr6.capacity_gbit = 0.34;
    // ~1e6 req/s at 1 GHz = one arrival per ~1000 cycles, far faster
    // than a 2-token gpt2-small service: a guaranteed overload.
    let spec = ArrivalSpec::Poisson { rate_per_s: 1_000_000.0 };
    let run = |seed: u64| {
        let at = arrivals::generate(&spec, 8, cfg.gddr6.freq_ghz, seed).unwrap();
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for (id, &arrival_cycle) in at.iter().enumerate() {
            let id = id as u64;
            ms.submit(StreamSpec { id, n_tokens: 2, arrival_cycle }).unwrap();
        }
        let n = ms.run_all().unwrap().len();
        ms.finalize_stats();
        assert_eq!(n, 8);
        (ms.kv_slots(), ms.stats.clone())
    };
    let (slots, stats) = run(7);
    assert!(slots < 4, "expected degraded capacity, got {slots} slots");
    assert_eq!(stats.peak_slots_in_use, slots as u64);
    assert!(stats.admission_blocked > 0);
    let lat = stats.latency_report().unwrap();
    assert!(lat.queue.p99 > 0, "overloaded run must show tail queueing");
    assert!(lat.ttft.p99 >= lat.queue.p99, "ttft includes the queue wait");
    assert!(lat.e2e.p99 >= lat.ttft.p99);
    // Determinism: same seed, same percentiles; the arrival trace
    // itself shifts with the seed.
    let (_, stats_again) = run(7);
    assert_eq!(stats_again.latency_report().unwrap(), lat);
    let a7 = arrivals::generate(&spec, 8, 1.0, 7).unwrap();
    let a8 = arrivals::generate(&spec, 8, 1.0, 8).unwrap();
    assert_ne!(a7, a8);
}

/// Open loop on the healthy config: a fixed-interval replay paced
/// slower than the service rate shows zero queueing (every request
/// admitted at its own arrival), while the same set compressed to
/// batch-at-zero queues on slot capacity — the generators and the
/// admission path agree end-to-end.
#[test]
fn fixed_interval_pacing_vs_batch_compression() {
    let m = by_name("gpt-nano").unwrap();
    let cfg = HwConfig::paper_baseline().with_max_streams(2);
    // Measure one request's service time to pace the open-loop run.
    let mut probe = MultiSim::new(&m, &cfg).unwrap();
    probe.submit(StreamSpec::new(0, 2)).unwrap();
    let service = probe.run_all().unwrap()[0].service_cycles();

    let interval = 2 * service; // slower than service on 2 slots
    let spec = ArrivalSpec::Fixed { interval_cycles: interval };
    let at = arrivals::generate(&spec, 6, cfg.gddr6.freq_ghz, 0).unwrap();
    let mut paced = MultiSim::new(&m, &cfg).unwrap();
    let mut batch = MultiSim::new(&m, &cfg).unwrap();
    for (id, &arrival_cycle) in at.iter().enumerate() {
        let id = id as u64;
        paced.submit(StreamSpec { id, n_tokens: 2, arrival_cycle }).unwrap();
        batch.submit(StreamSpec::new(id, 2)).unwrap();
    }
    let paced_results = paced.run_all().unwrap();
    let batch_results = batch.run_all().unwrap();
    for r in &paced_results {
        assert_eq!(r.queue_cycles(), 0, "request {} queued under slack pacing", r.id);
        assert_eq!(r.admitted_cycle, r.arrival_cycle);
    }
    let queued = batch_results.iter().filter(|r| r.queue_cycles() > 0).count();
    assert!(queued >= 4, "6 batch requests on 2 slots: {queued} queued");
}
