//! Multi-device sharding acceptance invariants: the `devices = 1`
//! fleet front end is byte-identical to the single-package engine on
//! arbitrary random traces (with batched decode and paged KV in every
//! combination), a 2-stage layer pipeline serves strictly more
//! co-resident streams than one package on the gpt2-xl Table-I
//! baseline, tensor parallelism strictly improves decode latency going
//! 1 -> 2 devices on the largest TP-capable paper models (link cycles
//! reported, never folded into compute), and the `kv_evict_watermark`
//! early-evict knob completes the oversubscription stress with fewer
//! page faults than demand-only eviction.

use pim_gpt::config::HwConfig;
use pim_gpt::mapping::PartitionStrategy;
use pim_gpt::model::gpt::by_name;
use pim_gpt::sim::{FleetSim, MultiSim, StreamOutcome, StreamResult, StreamSpec};

/// Keep the completions of a drained run, in completion order.
fn completed(outcomes: Vec<StreamOutcome>) -> Vec<StreamResult> {
    outcomes.into_iter().filter_map(StreamOutcome::into_completed).collect()
}

/// Everything the schedule determines, order-normalized: final clock,
/// instruction count, and per-stream (id, admitted, finish, per-token
/// finishes) rows.
type Signature = (u64, u64, Vec<(u64, u64, u64, Vec<u64>)>);

fn signature(outcomes: Vec<StreamOutcome>, clock: u64, instructions: u64) -> Signature {
    let mut rows: Vec<_> = completed(outcomes)
        .into_iter()
        .map(|r| (r.id, r.admitted_cycle, r.finish_cycle, r.token_finishes))
        .collect();
    rows.sort();
    (clock, instructions, rows)
}

/// `devices = 1` must be byte-identical to `MultiSim` — the fleet front
/// end *contains* the single-package engine and delegates, so any
/// divergence is a wrapper bug. Random traces (lengths, prompt splits,
/// arrival stamps) crossed with every batched-decode x paged-KV flag
/// combination.
#[test]
fn devices_one_is_byte_identical_on_random_traces() {
    use pim_gpt::util::prop::check;
    let m = by_name("gpt-nano").unwrap();
    check("fleet devices=1 identity", 6, |rng| {
        let max_streams = 1 + rng.gen_range(3) as usize;
        let n_streams = 2 + rng.gen_range(3);
        let specs: Vec<StreamSpec> = (0..n_streams)
            .map(|id| {
                let n_tokens = 2 + rng.gen_range(12);
                StreamSpec {
                    id,
                    n_tokens,
                    prompt_tokens: 1 + rng.gen_range(n_tokens),
                    arrival_cycle: rng.gen_range(2_000_000),
                }
            })
            .collect();
        for (batch, paging) in
            [(false, false), (true, false), (false, true), (true, true)]
        {
            let mut cfg = HwConfig::paper_baseline()
                .with_max_streams(max_streams)
                .with_batch_decode(batch)
                .with_devices(1);
            if paging {
                cfg.sched.kv_paging = true;
                cfg.sched.kv_page_tokens = 32;
                cfg.sched.kv_oversub = 1.5;
            }
            let mut ms = MultiSim::new(&m, &cfg).unwrap();
            let mut fleet = FleetSim::new(&m, &cfg).unwrap();
            assert_eq!(fleet.devices(), 1);
            for spec in &specs {
                ms.submit(*spec).unwrap();
                fleet.submit(*spec).unwrap();
            }
            let want_out = ms.run_all().unwrap();
            let got_out = fleet.run_all().unwrap();
            ms.finalize_stats();
            let want = signature(want_out, ms.clock(), ms.stats.instructions);
            let got_clock = fleet.clock();
            let got_instr = fleet.finalize_stats().instructions;
            let got = signature(got_out, got_clock, got_instr);
            if want != got {
                return Err(format!(
                    "batch={batch} paging={paging}: fleet devices=1 diverged \
                     (clock {} vs {})",
                    got.0, want.0
                ));
            }
        }
        Ok(())
    });
}

/// Tentpole acceptance pin: on the gpt2-xl Table-I baseline — where a
/// single package is KV-row-bound — a 2-stage layer pipeline grants
/// strictly more co-resident stream contexts (each device keeps its
/// whole channel/bank space for half the layers' weights and KV).
#[test]
fn gpt2_xl_pipeline_fleet_outgrants_single_package() {
    let m = by_name("gpt2-xl").unwrap();
    let cfg = HwConfig::paper_baseline().with_max_streams(8);
    let single = MultiSim::new(&m, &cfg).unwrap().kv_slots();
    assert!(
        (1..8).contains(&single),
        "premise: the Table-I baseline must be KV-bound below K=8, granted {single}"
    );
    let fleet_cfg = cfg.with_devices(2).with_partition(PartitionStrategy::LayerPipeline);
    let fleet = FleetSim::new(&m, &fleet_cfg).unwrap();
    assert_eq!(fleet.devices(), 2);
    assert!(
        fleet.kv_slots() > single,
        "2-device pipeline grants {} contexts, single package {single}",
        fleet.kv_slots()
    );
}

/// Tentpole acceptance pin: tensor parallelism strictly improves decode
/// latency going 1 -> 2 devices on the two largest TP-capable paper
/// models (gpt2-xl's 25 heads don't shard evenly — the partition pass
/// rejects it loudly, covered in the `mapping::partition` unit tests).
/// The all-reduce + LM-gather link cycles are reported explicitly and
/// per-device busy cycles match the device count.
#[test]
fn tensor_parallel_strictly_improves_decode_on_largest_models() {
    for name in ["gpt3-xl", "gpt3-large"] {
        let m = by_name(name).unwrap();
        let run = |devices: usize| {
            let cfg = HwConfig::paper_baseline()
                .with_devices(devices)
                .with_partition(PartitionStrategy::TensorParallel);
            let mut fleet = FleetSim::new(&m, &cfg).unwrap();
            fleet.submit(StreamSpec::new(0, 6)).unwrap();
            let r = completed(fleet.run_all().unwrap()).remove(0);
            assert_eq!(r.token_finishes.len(), 6, "{name}");
            // Decode share: everything past the first position.
            let decode = r.finish_cycle - r.token_finishes[0];
            let s = fleet.finalize_stats();
            (decode, s.cycles, s.link_transfer_cycles, s.device_busy_cycles.clone())
        };
        let (decode1, clock1, link1, busy1) = run(1);
        assert_eq!(link1, 0, "{name}: one device has no interconnect");
        assert!(busy1.is_empty(), "{name}: per-device counters are a fleet feature");
        let (decode2, clock2, link2, busy2) = run(2);
        assert!(
            decode2 < decode1,
            "{name}: TP decode latency regressed 1 -> 2 devices ({decode2} !< {decode1})"
        );
        assert!(clock2 < clock1, "{name}: makespan {clock2} !< {clock1}");
        assert!(link2 > 0, "{name}: all-reduce link cycles must be charged and reported");
        assert_eq!(busy2.len(), 2, "{name}");
        assert!(busy2.iter().all(|&b| b > 0), "{name}: idle device in lockstep TP");
    }
}

/// Satellite pin: the `kv_evict_watermark` low-watermark early-evict
/// completes the oversubscription stress (4 streams over-committing a
/// ~16-frame pool) with strictly fewer page faults than the default
/// demand-only eviction — frames are freed ahead of allocation, so the
/// free list stops running dry. At 0.0 (the default) the knob is off
/// and the demand path must still fault.
#[test]
fn kv_evict_watermark_cuts_page_faults_under_oversubscription() {
    let m = by_name("gpt2-small").unwrap();
    let run = |watermark: f64| {
        let mut cfg = HwConfig::paper_baseline()
            .with_max_streams(4)
            .with_kv_evict_watermark(watermark);
        cfg.gddr6.capacity_gbit = 0.34; // weights + ~2 whole contexts of rows
        cfg.sched.kv_paging = true;
        cfg.sched.kv_page_tokens = 128;
        cfg.sched.kv_oversub = 2.0;
        let mut ms = MultiSim::new(&m, &cfg).unwrap();
        for id in 0..4 {
            ms.submit(StreamSpec::with_prompt(id, 704, 64)).unwrap();
        }
        let results = completed(ms.run_all().unwrap());
        assert_eq!(results.len(), 4, "wm={watermark}: every stream completes");
        for r in &results {
            assert_eq!(r.tokens, 768, "wm={watermark}");
            assert_eq!(r.token_finishes.len(), 768, "wm={watermark}");
        }
        ms.finalize_stats();
        (ms.stats.page_faults, ms.stats.preemptions)
    };
    let (faults_off, _) = run(0.0);
    assert!(faults_off >= 1, "premise: the over-committed pool must fault without it");
    let (faults_on, preemptions_on) = run(0.25);
    assert!(
        faults_on < faults_off,
        "watermark eviction left {faults_on} faults, demand-only {faults_off}"
    );
    assert!(preemptions_on >= 1, "the watermark preempts ahead of demand, not never");
}
