//! PJRT artifact round-trip: load the AOT-compiled gpt-nano decode step,
//! generate tokens and check the golden sequence produced by the python
//! reference (`model.generate` in python/tests). Skipped gracefully when
//! `make artifacts` has not been run.

use std::path::Path;

use pim_gpt::runtime::{GptArtifact, PjrtRuntime};

fn artifact_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    dir.join("gpt-nano.meta.json").exists().then_some(dir)
}

#[test]
fn nano_generation_matches_python_golden() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let rt = PjrtRuntime::cpu().unwrap();
    let art = GptArtifact::load(rt, dir, "gpt-nano").unwrap();
    let toks = art.generate(&[1, 2, 3], 5).unwrap();
    // Golden from python: model.generate(cfg, params, [1,2,3], 5)
    assert_eq!(toks, vec![1, 2, 3, 295, 295, 295, 295, 295]);
}

#[test]
fn decode_is_deterministic_and_stateful() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let rt = PjrtRuntime::cpu().unwrap();
    let art = GptArtifact::load(rt, dir, "gpt-nano").unwrap();
    let (kc, vc) = art.empty_caches().unwrap();
    let (lg1, kc1, vc1) = art.decode(7, 0, &kc, &vc).unwrap();
    let (lg2, _, _) = art.decode(7, 0, &kc, &vc).unwrap();
    assert_eq!(lg1, lg2, "same input, same logits");
    // History must change the next step's output.
    let (lg_with, _, _) = art.decode(9, 1, &kc1, &vc1).unwrap();
    let (lg_no_hist, _, _) = art.decode(9, 1, &kc, &vc).unwrap();
    assert_ne!(lg_with, lg_no_hist, "cache must affect logits");
}

#[test]
fn rejects_out_of_range_position() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let rt = PjrtRuntime::cpu().unwrap();
    let art = GptArtifact::load(rt, dir, "gpt-nano").unwrap();
    let (kc, vc) = art.empty_caches().unwrap();
    let max = art.meta.max_seq as i32;
    assert!(art.decode(1, max, &kc, &vc).is_err());
}

#[test]
fn logits_are_finite() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let rt = PjrtRuntime::cpu().unwrap();
    let art = GptArtifact::load(rt, dir, "gpt-nano").unwrap();
    let (kc, vc) = art.empty_caches().unwrap();
    let (lg, _, _) = art.decode(0, 0, &kc, &vc).unwrap();
    assert_eq!(lg.len(), art.meta.vocab);
    assert!(lg.iter().all(|v| v.is_finite()));
}
