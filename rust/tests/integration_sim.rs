//! Full-system simulation invariants across models and configurations.

use pim_gpt::config::HwConfig;
use pim_gpt::energy::SystemEnergy;
use pim_gpt::model::gpt::by_name;
use pim_gpt::model::PAPER_MODELS;
use pim_gpt::sim::Simulator;

#[test]
fn all_paper_models_simulate() {
    for m in &PAPER_MODELS {
        let mut sim = Simulator::new(m, &HwConfig::paper_baseline()).unwrap();
        let r = sim.generate(4).unwrap();
        assert!(r.cycles() > 0, "{}", m.name);
        sim.finalize_stats();
        assert!(sim.stats.row_hit_rate() > 0.9, "{}", m.name);
        assert!(sim.stats.vmm_fraction() > 0.6, "{}", m.name);
    }
}

#[test]
fn latency_ordering_matches_model_size() {
    // Within each family, per-token latency grows with parameter count.
    let cfg = HwConfig::paper_baseline();
    let mut last = 0u64;
    for name in ["gpt2-small", "gpt2-medium", "gpt2-large", "gpt2-xl"] {
        let m = by_name(name).unwrap();
        let mut sim = Simulator::new(&m, &cfg).unwrap();
        let cycles = sim.generate(4).unwrap().cycles();
        assert!(cycles > last, "{name}: {cycles} <= {last}");
        last = cycles;
    }
}

#[test]
fn state_persists_across_steps() {
    // Row buffers stay open across tokens: the second nearly-identical
    // step is never much slower than the first.
    let m = by_name("gpt2-small").unwrap();
    let mut sim = Simulator::new(&m, &HwConfig::paper_baseline()).unwrap();
    let c1 = sim.decode_step(0).unwrap().cycles();
    let c2 = sim.decode_step(1).unwrap().cycles();
    // pos 1 attends over 2 tokens -> slightly more work, but within 5%
    assert!((c2 as f64) < (c1 as f64) * 1.05, "{c1} -> {c2}");
}

#[test]
fn energy_consistent_with_duration() {
    // Average power must land between idle floor and a loose peak bound.
    let m = by_name("gpt2-medium").unwrap();
    let mut sim = Simulator::new(&m, &HwConfig::paper_baseline()).unwrap();
    sim.generate(8).unwrap();
    sim.finalize_stats();
    let secs = sim.stats.seconds(1.0);
    let e = SystemEnergy::from_sim(&sim);
    let avg_w = e.total_j() / secs;
    assert!(avg_w > 0.5 && avg_w < 100.0, "avg power {avg_w} W");
}

#[test]
fn sensitivity_shapes_hold() {
    // Fig. 12/13 qualitative shapes on one model (fast versions).
    let m = by_name("gpt3-small").unwrap();
    let base = {
        let mut s = Simulator::new(&m, &HwConfig::paper_baseline()).unwrap();
        s.generate(8).unwrap().cycles()
    };
    // 10x slower ASIC: <= 30% slowdown (paper: worst 20% at full scale).
    let slow_asic = {
        let cfg = HwConfig::paper_baseline().with_asic_freq_ghz(0.1);
        let mut s = Simulator::new(&m, &cfg).unwrap();
        s.generate(8).unwrap().cycles()
    };
    let asic_ratio = slow_asic as f64 / base as f64;
    assert!(asic_ratio < 1.3, "asic ratio {asic_ratio}");
    // 16x slower interface: bounded (paper: ~2x at 1 Gb/s).
    let slow_bus = {
        let cfg = HwConfig::paper_baseline().with_data_rate_gbps(1.0);
        let mut s = Simulator::new(&m, &cfg).unwrap();
        s.generate(8).unwrap().cycles()
    };
    let bus_ratio = slow_bus as f64 / base as f64;
    assert!(bus_ratio > 1.1 && bus_ratio < 4.0, "bus ratio {bus_ratio}");
    // MAC lanes 16 -> 64: faster, sub-linear (paper: 1.8-2.0x).
    let wide = {
        let cfg = HwConfig::paper_baseline().with_mac_lanes(64);
        let mut s = Simulator::new(&m, &cfg).unwrap();
        s.generate(8).unwrap().cycles()
    };
    let speedup = base as f64 / wide as f64;
    assert!(speedup > 1.3 && speedup < 4.0, "mac speedup {speedup}");
}

#[test]
fn channel_scaling_near_linear() {
    let m = by_name("gpt3-small").unwrap();
    let t8 = {
        let mut s = Simulator::new(&m, &HwConfig::paper_baseline()).unwrap();
        s.generate(8).unwrap().cycles()
    };
    let t16 = {
        let cfg = HwConfig::paper_baseline().with_channels(16);
        let mut s = Simulator::new(&m, &cfg).unwrap();
        s.generate(8).unwrap().cycles()
    };
    let speedup = t8 as f64 / t16 as f64;
    assert!(speedup > 1.5 && speedup <= 2.05, "channel speedup {speedup}");
}

#[test]
fn long_context_grows_attention_cost() {
    let m = by_name("gpt3-small").unwrap();
    let mut sim = Simulator::new(&m, &HwConfig::paper_baseline()).unwrap();
    let early = sim.decode_step(1).unwrap().cycles();
    let late = sim.decode_step(2000).unwrap().cycles();
    assert!(late as f64 > 1.2 * early as f64, "{early} -> {late}");
}

#[test]
fn functional_configs_simulate_too() {
    for name in ["gpt-nano", "gpt-mini"] {
        let m = by_name(name).unwrap();
        let mut sim = Simulator::new(&m, &HwConfig::paper_baseline()).unwrap();
        assert!(sim.generate(4).unwrap().cycles() > 0);
    }
}
