//! Mapping invariants (Algorithm 3) across models and geometries,
//! property-style (see `util::prop`).

use pim_gpt::config::HwConfig;
use pim_gpt::mapping::ModelMapping;
use pim_gpt::model::gpt::by_name;
use pim_gpt::model::{DecodeGraph, PAPER_MODELS};
use pim_gpt::util::prop::check;
use pim_gpt::util::rng::Rng;

#[test]
fn every_model_maps_and_fills_consistently() {
    let cfg = HwConfig::paper_baseline();
    for m in &PAPER_MODELS {
        let mm = ModelMapping::build(m, &cfg).unwrap();
        assert!(mm.fill > 0.0 && mm.fill <= 1.0, "{}", m.name);
        // every weight element placed exactly once
        for (id, d_in, d_out) in DecodeGraph::weight_matrices(m) {
            let p = &mm.matrices[&id];
            assert_eq!(p.total_elems(cfg.gddr6.row_elems() as u32), d_in * d_out, "{:?}", id);
        }
    }
}

#[test]
fn prop_random_geometries_map_small_model() {
    check("random channel/bank geometry maps gpt2-small", 40, |rng: &mut Rng| {
        let m = by_name("gpt2-small").unwrap();
        let channels = [2usize, 4, 8, 16][rng.usize_in(0, 4)];
        let banks = [4usize, 8, 16][rng.usize_in(0, 3)];
        let mut cfg = HwConfig::paper_baseline();
        cfg.gddr6.channels = channels;
        cfg.gddr6.banks_per_channel = banks;
        let mm = ModelMapping::build(&m, &cfg)
            .map_err(|e| format!("{channels}x{banks}: {e}"))?;
        // coverage invariant under any geometry
        for (id, d_in, d_out) in DecodeGraph::weight_matrices(&m) {
            let p = &mm.matrices[&id];
            let got = p.total_elems(cfg.gddr6.row_elems() as u32);
            if got != d_in * d_out {
                return Err(format!("{id:?}: {got} != {}", d_in * d_out));
            }
        }
        Ok(())
    });
}

#[test]
fn kv_reads_cover_exactly_written_tokens() {
    // After t tokens, the K read plan must touch exactly t * d elements
    // and every row it touches must have been written by k_write — in
    // every stream slot independently.
    let cfg = HwConfig::paper_baseline();
    let m = by_name("gpt2-small").unwrap();
    let mm = ModelMapping::build(&m, &cfg).unwrap();
    assert!(mm.kv.n_slots >= 2, "paper baseline requests 4 slots");
    let d = m.d_model as u64;
    for slot in [0, mm.kv.n_slots - 1] {
        let mut written: std::collections::BTreeSet<(usize, u32)> = Default::default();
        for t in 0..300u64 {
            let (unit, segs) = mm.kv.k_write(0, slot, t);
            let u = unit.channel * cfg.gddr6.banks_per_channel + unit.bank;
            for s in &segs {
                written.insert((u, s.row));
            }
            let plans = mm.kv.k_read_plan(0, slot, t + 1);
            let total: u64 = plans.iter().flatten().map(|s| s.elems as u64).sum();
            assert_eq!(total, (t + 1) * d, "slot={slot} t={t}");
            for (u, plan) in plans.iter().enumerate() {
                for s in plan {
                    assert!(
                        written.contains(&(u, s.row)),
                        "slot={slot} t={t} unit {u} row {} unwritten",
                        s.row
                    );
                }
            }
        }
    }
}

#[test]
fn capacity_error_on_tiny_memory() {
    let m = by_name("gpt2-xl").unwrap();
    let mut cfg = HwConfig::paper_baseline();
    cfg.gddr6.capacity_gbit = 0.5; // 0.5 Gb/channel: 1.5B params cannot fit
    assert!(ModelMapping::build(&m, &cfg).is_err());
}

#[test]
fn prop_v_write_rows_disjoint_from_k_rows() {
    check("K and V regions never alias", 30, |rng: &mut Rng| {
        let cfg = HwConfig::paper_baseline();
        let m = by_name("gpt2-medium").unwrap();
        let mm = ModelMapping::build(&m, &cfg).unwrap();
        let layer = rng.usize_in(0, m.n_layer);
        let slot = rng.usize_in(0, mm.kv.n_slots);
        let t = rng.gen_range(m.max_seq as u64);
        let (unit, ksegs) = mm.kv.k_write(layer, slot, t);
        let u = unit.channel * cfg.gddr6.banks_per_channel + unit.bank;
        let (vbase, vcols, stride) = mm.kv.v_write(layer, slot, t, u);
        for ks in &ksegs {
            for c in 0..vcols {
                let vrow = vbase + c * stride;
                if ks.row == vrow {
                    return Err(format!(
                        "layer {layer} slot {slot} t {t} unit {u} row {vrow} aliased"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kv_writes_disjoint_across_slots() {
    // Cross-slot isolation: the same (layer, token) write in different
    // slots must never touch a shared row of the same unit.
    check("slots never alias", 30, |rng: &mut Rng| {
        let cfg = HwConfig::paper_baseline();
        let m = by_name("gpt2-small").unwrap();
        let mm = ModelMapping::build(&m, &cfg).unwrap();
        let layer = rng.usize_in(0, m.n_layer);
        let t = rng.gen_range(m.max_seq as u64);
        let a = rng.usize_in(0, mm.kv.n_slots);
        let b = rng.usize_in(0, mm.kv.n_slots);
        if a == b {
            return Ok(());
        }
        let (_, ksegs_a) = mm.kv.k_write(layer, a, t);
        let (_, ksegs_b) = mm.kv.k_write(layer, b, t);
        for (sa, sb) in ksegs_a.iter().zip(&ksegs_b) {
            if sa.row == sb.row {
                return Err(format!("layer {layer} t {t}: slots {a}/{b} share K row {}", sa.row));
            }
        }
        let u = rng.usize_in(0, mm.kv.n_units);
        let (va, cols_a, stride) = mm.kv.v_write(layer, a, t, u);
        let (vb, cols_b, _) = mm.kv.v_write(layer, b, t, u);
        assert_eq!(cols_a, cols_b);
        // Column rows are `base + c * stride`: the whole ranges must be
        // disjoint, not just the bases.
        let (end_a, end_b) = (va + cols_a * stride, vb + cols_b * stride);
        if va < end_b && vb < end_a {
            return Err(format!("slots {a}/{b} V ranges overlap: [{va},{end_a}) vs [{vb},{end_b})"));
        }
        Ok(())
    });
}
