//! Profiler acceptance invariants (PR tentpole): the profiling observer
//! aggregates the trace stream into a cycle-attribution tree that
//! reconciles **exactly** against the engine's busy/link cycle
//! aggregates, without moving a single simulated cycle. Pinned here on
//! random traces crossed with batched decode x paged KV x device count,
//! plus the cost-table calibration error bounds and the satellite
//! golden on two-device timeline link binning.

use pim_gpt::config::HwConfig;
use pim_gpt::mapping::PartitionStrategy;
use pim_gpt::model::gpt::by_name;
use pim_gpt::sim::{calibrate, FleetSim, Profile, StreamOutcome, StreamSpec};

/// Everything the schedule determines, order-normalized: final clock,
/// token count, and per-stream (id, admitted, finish, per-token
/// finishes) rows.
type Signature = (u64, u64, Vec<(u64, u64, u64, Vec<u64>)>);

/// Run one fleet config to completion; return the schedule signature,
/// the finished profile (None when profiling is off) and the
/// reconciliation targets (busy cycles, link cycles).
fn run_fleet(
    m: &pim_gpt::model::GptModel,
    cfg: &HwConfig,
    specs: &[StreamSpec],
) -> (Signature, Option<Profile>, u64, u64) {
    let mut fleet = FleetSim::new(m, cfg).unwrap();
    for spec in specs {
        fleet.submit(*spec).unwrap();
    }
    let out = fleet.run_all().unwrap();
    let clock = fleet.clock();
    let tokens = fleet.finalize_stats().tokens;
    let busy = fleet.stats().busy_cycles();
    let link = fleet.stats().link_transfer_cycles;
    let mut rows: Vec<_> = out
        .into_iter()
        .filter_map(StreamOutcome::into_completed)
        .map(|r| (r.id, r.admitted_cycle, r.finish_cycle, r.token_finishes))
        .collect();
    rows.sort();
    ((clock, tokens, rows), fleet.profile_report(), busy, link)
}

/// Acceptance pin: profiling is observer-effect free and the
/// attribution reconciles exactly. On random traces crossed with
/// batched decode x paged KV x devices in {1, 2}, the profiled run's
/// schedule is byte-identical to the unprofiled one, and the finished
/// profile satisfies leaf sums + residual == `SimStats::busy_cycles`
/// (residual >= 0) with link spans summing exactly to
/// `SimStats::link_transfer_cycles`.
#[test]
fn profiling_reconciles_exactly_and_never_moves_a_cycle() {
    use pim_gpt::util::prop::check;
    let m = by_name("gpt-nano").unwrap();
    check("profiling reconciles + observer-effect-free", 4, |rng| {
        let n_streams = 2 + rng.gen_range(3);
        let specs: Vec<StreamSpec> = (0..n_streams)
            .map(|id| {
                let n_tokens = 2 + rng.gen_range(10);
                StreamSpec {
                    id,
                    n_tokens,
                    prompt_tokens: 1 + rng.gen_range(n_tokens),
                    arrival_cycle: rng.gen_range(1_000_000),
                }
            })
            .collect();
        for devices in [1usize, 2] {
            for (batch, paging) in
                [(false, false), (true, false), (false, true), (true, true)]
            {
                let mut base = HwConfig::paper_baseline()
                    .with_max_streams(2)
                    .with_batch_decode(batch)
                    .with_devices(devices);
                if paging {
                    base.sched.kv_paging = true;
                    base.sched.kv_page_tokens = 32;
                    base.sched.kv_oversub = 1.5;
                }
                let (want, none, _, _) = run_fleet(&m, &base, &specs);
                assert!(none.is_none(), "unprofiled run produced a profile");
                let (sig, profile, busy, link) =
                    run_fleet(&m, &base.clone().with_profile("json:p.json"), &specs);
                if sig != want {
                    return Err(format!(
                        "devices={devices} batch={batch} paging={paging}: profiling \
                         changed the schedule (clock {} vs {})",
                        sig.0, want.0
                    ));
                }
                let p = profile.expect("profiled run produced no report");
                p.check().map_err(|e| {
                    format!("devices={devices} batch={batch} paging={paging}: {e}")
                })?;
                if p.attributed_cycles() + p.residual as u64 != busy {
                    return Err(format!(
                        "attribution {} + residual {} != busy {busy}",
                        p.attributed_cycles(),
                        p.residual
                    ));
                }
                let link_sum: u64 = p.links.iter().map(|(_, c)| c).sum();
                if link_sum != link {
                    return Err(format!("link spans sum {link_sum} != charged {link}"));
                }
                if devices == 2 && link == 0 {
                    return Err("two-device run charged no link cycles".into());
                }
            }
        }
        Ok(())
    });
}

/// Offline replay equivalence: aggregating a recorded `jsonl:` trace
/// through `Profile::from_jsonl` produces the same attribution leaves,
/// link sums and histogram counts as the online observer that watched
/// the identical run.
#[test]
fn from_jsonl_replay_matches_the_online_profile() {
    let m = by_name("gpt-nano").unwrap();
    let cfg = HwConfig::paper_baseline()
        .with_max_streams(2)
        .with_batch_decode(true)
        .with_trace("jsonl:t.jsonl")
        .with_profile("text:p.txt");
    let mut fleet = FleetSim::new(&m, &cfg).unwrap();
    for id in 0..3 {
        fleet.submit(StreamSpec::with_prompt(id, 3, 4)).unwrap();
    }
    assert_eq!(fleet.run_all().unwrap().len(), 3);
    fleet.finalize_stats();
    let online = fleet.profile_report().expect("no online profile");
    online.check().expect("online profile must reconcile");
    let (_, jsonl) = fleet.render_trace().expect("no jsonl artifact");
    let offline = Profile::from_jsonl(&jsonl, &m, &cfg).expect("replay failed");
    offline.check().expect("offline profile must reconcile");
    assert_eq!(offline.residual, 0, "offline replay pins busy to the covered sum");
    assert_eq!(online.leaves, offline.leaves, "attribution trees diverge");
    assert_eq!(online.links, offline.links, "link sums diverge");
    let counts = |p: &Profile| -> Vec<(String, u64)> {
        p.histograms.iter().map(|(k, h)| (k.clone(), h.count())).collect()
    };
    assert_eq!(counts(&online), counts(&offline), "histogram populations diverge");
}

/// Acceptance pin: the calibrated cost table predicts end-to-end
/// request cycles within 5% mean / 15% max relative error on held-out
/// validation requests, across four paper models. The same bounds are
/// recorded by CI into `BENCH_calibration.json`.
#[test]
fn cost_table_calibration_error_is_bounded_across_the_zoo() {
    let cfg = HwConfig::paper_baseline();
    for name in ["gpt2-small", "gpt2-medium", "gpt2-large", "gpt2-xl"] {
        let m = by_name(name).unwrap();
        let rep = calibrate(&m, &cfg, 7, 6).unwrap();
        assert_eq!(rep.rows.len(), 6, "{name}: expected 6 validation rows");
        assert!(
            rep.mean_rel_err <= 0.05,
            "{name}: mean rel err {:.4} > 5%",
            rep.mean_rel_err
        );
        assert!(
            rep.max_rel_err <= 0.15,
            "{name}: max rel err {:.4} > 15%",
            rep.max_rel_err
        );
    }
}

/// Satellite golden: at two devices the windowed timeline bins link
/// cycles correctly — windows tile [0, makespan) contiguously, busy +
/// idle fills each window exactly, and the per-window link charges sum
/// to `SimStats::link_transfer_cycles` (nonzero for a layer pipeline).
#[test]
fn timeline_windows_bin_link_cycles_exactly_at_two_devices() {
    let m = by_name("gpt2-small").unwrap();
    let cfg = HwConfig::paper_baseline()
        .with_max_streams(2)
        .with_devices(2)
        .with_partition(PartitionStrategy::LayerPipeline)
        .with_trace_window(2_000);
    let mut fleet = FleetSim::new(&m, &cfg).unwrap();
    for id in 0..2 {
        fleet.submit(StreamSpec::with_prompt(id, 4, 4)).unwrap();
    }
    assert_eq!(fleet.run_all().unwrap().len(), 2);
    let clock = fleet.clock();
    let stats = fleet.finalize_stats().clone();
    let tl = &stats.timeline;
    assert!(!tl.is_empty(), "trace_window produced no timeline");
    assert_eq!(tl[0].start, 0);
    assert_eq!(tl.last().unwrap().end, clock, "windows must cover [0, makespan)");
    for pair in tl.windows(2) {
        assert_eq!(pair[0].end, pair[1].start, "windows not contiguous");
    }
    for w in tl {
        assert_eq!(w.busy + w.idle, w.end - w.start, "busy+idle must fill the window");
    }
    let busy_sum: u64 = tl.iter().map(|w| w.busy).sum();
    assert_eq!(busy_sum, stats.busy_cycles(), "window busy sums != busy cycles");
    let link_sum: u64 = tl.iter().map(|w| w.link).sum();
    assert_eq!(
        link_sum, stats.link_transfer_cycles,
        "window link sums != charged link transfer cycles"
    );
    assert!(link_sum > 0, "layer pipeline paid no link cycles");
}
