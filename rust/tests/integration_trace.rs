//! Tracing acceptance invariants (PR tentpole): the trace layer is an
//! observer, never a participant. Tracing **off** is the default and
//! costs one dead branch; tracing **on** (either sink) must not move a
//! single simulated cycle — pinned here on random traces crossed with
//! batched decode x paged KV x device count. The traced event tallies
//! must reconcile exactly with the `SimStats` aggregates, the JSONL
//! artifact must parse line-by-line, and the Chrome artifact must pass
//! structural validation with the fault -> writeback -> restore
//! sequence landing on the victim's track in order.

use pim_gpt::config::HwConfig;
use pim_gpt::model::gpt::by_name;
use pim_gpt::sim::{validate_chrome, FleetSim, MultiSim, StreamOutcome, StreamSpec};
use pim_gpt::util::json::Json;

/// Everything the schedule determines, order-normalized: final clock,
/// token count, and per-stream (id, admitted, finish, per-token
/// finishes) rows.
type Signature = (u64, u64, Vec<(u64, u64, u64, Vec<u64>)>);

fn signature(outcomes: Vec<StreamOutcome>, clock: u64, tokens: u64) -> Signature {
    let mut rows: Vec<_> = outcomes
        .into_iter()
        .filter_map(StreamOutcome::into_completed)
        .map(|r| (r.id, r.admitted_cycle, r.finish_cycle, r.token_finishes))
        .collect();
    rows.sort();
    (clock, tokens, rows)
}

/// Run one fleet config to completion and return its signature plus the
/// rendered trace artifact (None when tracing is off).
fn run_fleet(
    m: &pim_gpt::model::GptModel,
    cfg: &HwConfig,
    specs: &[StreamSpec],
) -> (Signature, Option<(String, String)>) {
    let mut fleet = FleetSim::new(m, cfg).unwrap();
    for spec in specs {
        fleet.submit(*spec).unwrap();
    }
    let out = fleet.run_all().unwrap();
    let clock = fleet.clock();
    // finalize_stats reconciles trace counts against the aggregates
    // under debug_assertions — a mismatch panics right here.
    let tokens = fleet.finalize_stats().tokens;
    let sig = signature(out, clock, tokens);
    (sig, fleet.render_trace())
}

/// Acceptance pin: tracing (off / jsonl / chrome) is observer-effect
/// free. All three runs of the same random trace produce byte-identical
/// schedules across every batched-decode x paged-KV x devices
/// combination; the JSONL artifact parses per line, the Chrome artifact
/// passes structural validation, and (satellite 1) the traced tallies
/// reconcile with `SimStats` — enforced by the `debug_assertions` check
/// inside `finalize_stats`, which `cargo test` builds always run.
#[test]
fn tracing_is_observer_effect_free_on_random_traces() {
    use pim_gpt::util::prop::check;
    let m = by_name("gpt-nano").unwrap();
    check("tracing observer-effect-free", 4, |rng| {
        let n_streams = 2 + rng.gen_range(3);
        let specs: Vec<StreamSpec> = (0..n_streams)
            .map(|id| {
                let n_tokens = 2 + rng.gen_range(10);
                StreamSpec {
                    id,
                    n_tokens,
                    prompt_tokens: 1 + rng.gen_range(n_tokens),
                    arrival_cycle: rng.gen_range(1_000_000),
                }
            })
            .collect();
        for devices in [1usize, 2] {
            for (batch, paging) in
                [(false, false), (true, false), (false, true), (true, true)]
            {
                let mut base = HwConfig::paper_baseline()
                    .with_max_streams(2)
                    .with_batch_decode(batch)
                    .with_devices(devices);
                if paging {
                    base.sched.kv_paging = true;
                    base.sched.kv_page_tokens = 32;
                    base.sched.kv_oversub = 1.5;
                }
                let (want, none) = run_fleet(&m, &base, &specs);
                assert!(none.is_none(), "untraced run rendered an artifact");
                let (jsonl_sig, jsonl) =
                    run_fleet(&m, &base.clone().with_trace("jsonl:t.jsonl"), &specs);
                let (chrome_sig, chrome) =
                    run_fleet(&m, &base.clone().with_trace("chrome:t.json"), &specs);
                if jsonl_sig != want || chrome_sig != want {
                    return Err(format!(
                        "devices={devices} batch={batch} paging={paging}: tracing \
                         changed the schedule (clock {} / {} vs {})",
                        jsonl_sig.0, chrome_sig.0, want.0
                    ));
                }
                let (path, contents) = jsonl.expect("jsonl run rendered no artifact");
                assert_eq!(path, "t.jsonl");
                for line in contents.lines() {
                    let ev = Json::parse(line)
                        .map_err(|e| format!("jsonl line does not parse: {e}: {line}"))?;
                    if ev.get("ev").and_then(Json::as_str).is_none() {
                        return Err(format!("jsonl line without ev tag: {line}"));
                    }
                }
                let (path, contents) = chrome.expect("chrome run rendered no artifact");
                assert_eq!(path, "t.json");
                let n = validate_chrome(&contents)
                    .map_err(|e| format!("chrome validation failed: {e}"))?;
                if n == 0 {
                    return Err("chrome trace has no events".into());
                }
            }
        }
        Ok(())
    });
}

/// Satellite pin: the traced tallies agree with the aggregate counters
/// field by field on a paged, batched, eviction-heavy single-package
/// run — the reconciliation contract spelled out, not just the
/// debug-assert inside `finalize_stats`.
#[test]
fn trace_counts_reconcile_with_stats_field_by_field() {
    let m = by_name("gpt2-small").unwrap();
    let mut cfg = HwConfig::paper_baseline()
        .with_max_streams(4)
        .with_batch_decode(true)
        .with_trace("jsonl:t.jsonl");
    cfg.gddr6.capacity_gbit = 0.34;
    cfg.sched.kv_paging = true;
    cfg.sched.kv_page_tokens = 128;
    cfg.sched.kv_oversub = 2.0;
    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    for id in 0..4 {
        ms.submit(StreamSpec::with_prompt(id, 704, 64)).unwrap();
    }
    let done = ms.run_all().unwrap().len();
    assert_eq!(done, 4);
    ms.finalize_stats();
    let c = ms.trace_counts().clone();
    let s = &ms.stats;
    assert_eq!(c.tokens, s.tokens);
    assert_eq!(c.prefill_chunks, s.prefill_chunks);
    assert_eq!(c.solo_decode_steps, s.solo_decode_steps);
    assert_eq!(c.fused_sweeps, s.fused_sweeps);
    assert_eq!(c.fused_streams, s.fused_streams);
    assert_eq!(c.page_faults, s.page_faults);
    assert_eq!(c.evictions, s.preemptions);
    assert_eq!(c.rejects, s.rejected);
    assert_eq!(c.retires, s.streams.len() as u64);
    assert!(c.page_faults >= 1, "premise: the over-committed pool must fault");
    assert_eq!(c.evictions, c.writebacks, "every eviction drains a writeback");
    assert!(c.restores >= 1, "an evicted stream must restore to finish");
}

/// Chrome-trace span-nesting acceptance: on an eviction-heavy paged
/// run, the victim's track shows the preemption in causal order — an
/// `evict` instant, then the `writeback` span, and a later `restore`
/// span that begins only after the writeback ends. The whole artifact
/// passes structural validation (per-track monotonic timestamps, every
/// B closed by a matching E).
#[test]
fn chrome_trace_orders_fault_writeback_restore_on_victim_track() {
    let m = by_name("gpt2-small").unwrap();
    let mut cfg = HwConfig::paper_baseline()
        .with_max_streams(4)
        .with_trace("chrome:trace.json");
    cfg.gddr6.capacity_gbit = 0.34;
    cfg.sched.kv_paging = true;
    cfg.sched.kv_page_tokens = 128;
    cfg.sched.kv_oversub = 2.0;
    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    for id in 0..4 {
        ms.submit(StreamSpec::with_prompt(id, 704, 64)).unwrap();
    }
    assert_eq!(ms.run_all().unwrap().len(), 4);
    ms.finalize_stats();
    let (_, contents) = ms.render_trace().expect("no chrome artifact");
    let n = validate_chrome(&contents).expect("chrome validation");
    assert!(n > 0);
    // Collect (name, ph, ts) per stream track.
    let root = Json::parse(&contents).unwrap();
    let events = root.get("traceEvents").unwrap().as_arr().unwrap();
    let mut tracks: std::collections::BTreeMap<u64, Vec<(String, String, u64)>> =
        Default::default();
    for ev in events {
        let ph = ev.get("ph").unwrap().as_str().unwrap();
        if ph == "M" {
            continue;
        }
        let tid = ev.get("tid").unwrap().as_f64().unwrap() as u64;
        let name = ev.get("name").unwrap().as_str().unwrap().to_string();
        let ts = ev.get("ts").unwrap().as_f64().unwrap() as u64;
        tracks.entry(tid).or_default().push((name, ph.to_string(), ts));
    }
    let fault = events
        .iter()
        .any(|e| e.get("name").and_then(Json::as_str) == Some("page_fault"));
    assert!(fault, "premise: the over-committed pool must fault");
    // At least one victim shows evict -> writeback -> restore in order
    // on its own track (rows are already per-track time-sorted).
    let mut nested = 0usize;
    for rows in tracks.values() {
        let pos = |name: &str, ph: &str| {
            rows.iter().position(|(n, p, _)| n == name && p == ph)
        };
        let (Some(ev), Some(wb_b), Some(wb_e)) =
            (pos("evict", "i"), pos("writeback", "B"), pos("writeback", "E"))
        else {
            continue;
        };
        assert!(ev <= wb_b, "writeback began before the evict decision");
        assert!(wb_b < wb_e);
        if let Some(rs_b) = pos("restore", "B") {
            assert!(
                rows[rs_b].2 >= rows[wb_e].2,
                "restore began at {} before writeback ended at {}",
                rows[rs_b].2,
                rows[wb_e].2
            );
            nested += 1;
        }
    }
    assert!(nested >= 1, "no track shows the evict -> writeback -> restore sequence");
}

/// Golden lifecycle order on a deterministic single-stream gpt-nano
/// run: the JSONL log opens with `submit`, admits exactly once, the
/// compute spans account for every token position, and `stream_retire`
/// closes the log. Event stamps never decrease per stream.
#[test]
fn jsonl_lifecycle_order_is_golden_on_gpt_nano() {
    let m = by_name("gpt-nano").unwrap();
    let cfg = HwConfig::paper_baseline().with_trace("jsonl:t.jsonl");
    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    ms.submit(StreamSpec::with_prompt(0, 1, 2)).unwrap();
    assert_eq!(ms.run_all().unwrap().len(), 1);
    ms.finalize_stats();
    let (_, contents) = ms.render_trace().expect("no jsonl artifact");
    let names: Vec<String> = contents
        .lines()
        .map(|l| {
            Json::parse(l).unwrap().get("ev").unwrap().as_str().unwrap().to_string()
        })
        .collect();
    assert_eq!(names.first().map(String::as_str), Some("submit"));
    assert_eq!(names.last().map(String::as_str), Some("stream_retire"));
    assert_eq!(names.iter().filter(|n| *n == "admit").count(), 1);
    assert_eq!(names.iter().filter(|n| *n == "stream_retire").count(), 1);
    let admit = names.iter().position(|n| n == "admit").unwrap();
    let first_span = names
        .iter()
        .position(|n| n == "prefill_chunk" || n == "decode_step")
        .expect("no compute spans");
    assert!(admit < first_span, "compute before admission");
    // Positions produced must cover all 3 tokens (1 prompt + 2 gen).
    let mut produced = 0u64;
    for l in contents.lines() {
        let ev = Json::parse(l).unwrap();
        match ev.get("ev").unwrap().as_str().unwrap() {
            "prefill_chunk" => {
                produced += ev.get("positions").unwrap().as_f64().unwrap() as u64
            }
            "decode_step" => produced += 1,
            _ => {}
        }
    }
    assert_eq!(produced, 3);
}

/// Tracing off is genuinely off: no artifact, all tallies zero.
#[test]
fn tracing_off_renders_nothing_and_counts_nothing() {
    let m = by_name("gpt-nano").unwrap();
    let cfg = HwConfig::paper_baseline();
    let mut ms = MultiSim::new(&m, &cfg).unwrap();
    ms.submit(StreamSpec::new(0, 3)).unwrap();
    assert_eq!(ms.run_all().unwrap().len(), 1);
    ms.finalize_stats();
    assert!(ms.render_trace().is_none());
    assert_eq!(*ms.trace_counts(), Default::default());
    assert!(ms.stats.timeline.is_empty(), "no timeline without trace_window");
}
