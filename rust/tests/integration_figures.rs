//! Figure-harness integration: every experiment regenerates and its
//! paper-shape assertions hold at reduced scale.

use pim_gpt::config::HwConfig;
use pim_gpt::report;

#[test]
fn fig8_9_bands_at_reduced_scale() {
    let r = report::fig8_9_speedup_energy(16).unwrap();
    let arr = r.json.as_arr().unwrap();
    assert_eq!(arr.len(), 8);
    let get = |i: usize, k: &str| arr[i].get(k).unwrap().as_f64().unwrap();
    for i in 0..8 {
        // Loose bounds at 16 tokens; full bands checked in benches.
        assert!(get(i, "speedup_gpu") > 20.0, "row {i}");
        assert!(get(i, "speedup_cpu") > 200.0, "row {i}");
        assert!(get(i, "energy_eff_gpu") > 50.0, "row {i}");
        assert!(get(i, "energy_eff_cpu") > get(i, "energy_eff_gpu"), "row {i}");
    }
    // Monotone: small models gain the most vs GPU (paper Fig. 8 shape).
    assert!(get(0, "speedup_gpu") > get(3, "speedup_gpu"));
    assert!(get(4, "speedup_gpu") > get(7, "speedup_gpu"));
}

#[test]
fn fig10_arithmetic_small_share() {
    // VMM dominates and arithmetic stays a sliver (paper Fig. 10). The
    // remainder is mostly the KV write-back, whose column-major V write
    // serializes ACT+WR+PRE per element over the channel bus (§IV.B) —
    // it is reported as its own share and must stay below VMM.
    let r = report::fig10_breakdown(8).unwrap();
    for row in r.json.as_arr().unwrap() {
        let vmm = row.get("vmm_share").unwrap().as_f64().unwrap();
        let arith = row.get("arith_share").unwrap().as_f64().unwrap();
        let kvw = row.get("kvwrite_share").unwrap().as_f64().unwrap();
        assert!(vmm > 0.6, "vmm {vmm}");
        assert!(arith < 0.15, "arith {arith}");
        assert!(kvw < vmm, "kv write {kvw} vs vmm {vmm}");
        assert!(vmm / (vmm + arith) > 0.9, "vmm {vmm} vs arith {arith}");
    }
    // GPT3-XL (second row) more VMM-dominated than GPT3-small (first).
    let arr = r.json.as_arr().unwrap();
    let s = arr[0].get("vmm_share").unwrap().as_f64().unwrap();
    let xl = arr[1].get("vmm_share").unwrap().as_f64().unwrap();
    assert!(xl > s, "{xl} vs {s}");
}

#[test]
fn fig11_hit_rate_and_reduction() {
    let r = report::fig11_locality(16).unwrap();
    for row in r.json.as_arr().unwrap() {
        let hit = row.get("row_hit_rate").unwrap().as_f64().unwrap();
        let red = row.get("reduction").unwrap().as_f64().unwrap();
        assert!(hit > 0.95, "hit {hit}");
        assert!(red > 50.0, "reduction {red}");
    }
}

#[test]
fn fig12_insensitive_to_asic_freq() {
    let r = report::fig12_asic_freq(8).unwrap();
    for row in r.json.as_arr().unwrap() {
        let norm = row.get("normalized").unwrap().as_arr().unwrap();
        let worst = norm.last().unwrap().as_f64().unwrap(); // 100 MHz
        assert!(worst < 1.35, "{}: {worst}", row.get("model").unwrap());
    }
}

#[test]
fn fig13_bandwidth_sensitivity_bounded() {
    let r = report::fig13_bandwidth(8).unwrap();
    for row in r.json.as_arr().unwrap() {
        let norm = row.get("normalized").unwrap().as_arr().unwrap();
        let at_1gbps = norm.last().unwrap().as_f64().unwrap();
        assert!(at_1gbps > 1.05 && at_1gbps < 4.5, "{at_1gbps}");
    }
}

#[test]
fn fig14_superlinear_growth() {
    let r = report::fig14_long_token(&[64, 128, 256]).unwrap();
    let arr = r.json.as_arr().unwrap();
    let n0 = arr[0].get("seconds").unwrap().as_f64().unwrap();
    let n2 = arr[2].get("seconds").unwrap().as_f64().unwrap();
    // 4x tokens must cost more than 4x time (attention grows).
    assert!(n2 > 4.0 * n0, "{n0} -> {n2}");
}

#[test]
fn fig15_mac_and_channel_scaling() {
    let r = report::fig15_scalability(8).unwrap();
    for row in r.json.as_arr().unwrap() {
        let knob = row.get("knob").unwrap().as_str().unwrap();
        let v = row.get("value").unwrap().as_usize().unwrap();
        let s = row.get("speedup").unwrap().as_f64().unwrap();
        match (knob, v) {
            ("mac_lanes", 16) | ("channels", 8) => assert!((s - 1.0).abs() < 1e-9),
            // Wider MACs speed only the reads; the serialized V
            // write-back (lanes-independent, §IV.B) dilutes the gain at
            // these short contexts, so the band starts below the
            // paper's long-context 1.8-2.0x.
            ("mac_lanes", 64) => assert!(s > 1.2 && s < 4.0, "mac64 {s}"),
            ("channels", 32) => assert!(s > 2.0 && s < 4.2, "ch32 {s}"),
            _ => assert!(s >= 1.0),
        }
    }
}

#[test]
fn table1_matches_paper_defaults() {
    let r = report::table1_config(&HwConfig::paper_baseline());
    for needle in ["8 x 16", "2048 B / 16384", "16 pins x 16 Gb/s", "256 / 128", "0.64 mm2 / 304.59 mW"] {
        assert!(r.rendered.contains(needle), "missing {needle}\n{}", r.rendered);
    }
}

#[test]
fn table2_beats_prior_accelerators() {
    let r = report::table2_comparison(32).unwrap();
    let speedup = r.json.get("speedup").unwrap().as_f64().unwrap();
    // All prior speedups are <= 35x; PIM-GPT must clear them.
    assert!(speedup > 35.0, "{speedup}");
}

#[test]
fn serving_tail_latency_deterministic_and_ordered() {
    let r = report::fig_serving_tail_latency(5, 2, &[0.5, 2.0], 7).unwrap();
    let rows = r.json.as_arr().unwrap();
    // 8 paper models x 2 load points.
    assert_eq!(rows.len(), 16);
    for row in rows {
        let f = |k: &str| row.get(k).unwrap().as_f64().unwrap();
        assert!(f("ttft_p50_cycles") > 0.0);
        assert!(f("ttft_p50_cycles") <= f("ttft_p99_cycles"));
        assert!(f("ttft_p99_cycles") <= f("e2e_p99_cycles"));
        assert!(f("rate_per_s") > 0.0);
    }
    // Identical seed -> identical percentiles (no wall clock / OS RNG).
    let again = report::fig_serving_tail_latency(5, 2, &[0.5, 2.0], 7).unwrap();
    assert_eq!(r.json, again.json);
}

#[test]
fn policy_comparison_covers_models_and_policies() {
    let r = report::fig_policy_comparison(5, 2, 1.5, 7).unwrap();
    let rows = r.json.as_arr().unwrap();
    // 8 paper models x 4 policies.
    assert_eq!(rows.len(), 32);
    let mut policies_seen = std::collections::BTreeSet::new();
    for row in rows {
        let f = |k: &str| row.get(k).unwrap().as_f64().unwrap();
        policies_seen.insert(row.get("policy").unwrap().as_str().unwrap().to_string());
        assert!(f("ttft_p50_cycles") > 0.0);
        assert!(f("ttft_p50_cycles") <= f("ttft_p99_cycles"));
        assert!(f("ttft_p99_cycles") <= f("e2e_p99_cycles"));
        assert!(f("makespan_cycles") > 0.0);
        let rejected = f("rejected");
        let policy = row.get("policy").unwrap().as_str().unwrap();
        if policy != "slo" {
            assert_eq!(rejected, 0.0, "{policy} must never shed");
        }
        assert!(f("slo_ttft_budget_cycles") >= 1.0);
    }
    let want: std::collections::BTreeSet<String> =
        ["fcfs", "srf", "fair", "slo"].iter().map(|s| s.to_string()).collect();
    assert_eq!(policies_seen, want);
    // Identical seed -> identical table (policies are deterministic).
    let again = report::fig_policy_comparison(5, 2, 1.5, 7).unwrap();
    assert_eq!(r.json, again.json);
}
