//! Edge-serving scenario (the paper's motivating deployment): PIM-GPT as
//! an on-device inference engine, where the ASIC clock is scaled down to
//! save power (Fig. 12's claim: performance is insensitive to ASIC
//! frequency, justifying edge frequency scaling).
//!
//! Serves the same request trace at 1 GHz, 200 MHz and 100 MHz ASIC
//! clocks and reports simulated latency + energy per configuration.
//!
//! ```bash
//! cargo run --release --example edge_serving
//! ```

use pim_gpt::config::HwConfig;
use pim_gpt::coordinator::{PimGptSystem, Request, Server};
use pim_gpt::model::gpt::by_name;

fn serve_trace(cfg: HwConfig, model: &str, n_req: u64) -> anyhow::Result<(f64, f64)> {
    let name = model.to_string();
    let mut server = Server::start(move || {
        let m = by_name(&name).unwrap();
        PimGptSystem::timing_only(&m, &cfg)
    });
    for id in 0..n_req {
        server.submit(Request {
            id,
            prompt: (1..=4 + (id % 4) as i32).collect(),
            n_new: 24,
            arrival_cycle: 0,
        })?;
    }
    let mut sim_s = 0.0;
    let mut toks = 0u64;
    for _ in 0..n_req {
        let r = server.recv()?;
        anyhow::ensure!(r.error.is_none(), "request failed: {:?}", r.error);
        sim_s += r.sim_seconds;
        toks += r.tokens.len() as u64;
    }
    server.shutdown();
    Ok((sim_s, toks as f64))
}

fn main() -> anyhow::Result<()> {
    let model = "gpt2-small";
    println!("== edge serving: ASIC frequency scaling on {model} ==\n");
    println!("{:<10} {:>14} {:>14} {:>10}", "ASIC clk", "sim latency", "per token", "vs 1 GHz");
    let mut base = None;
    for freq in [1.0, 0.5, 0.2, 0.1] {
        let cfg = HwConfig::paper_baseline().with_asic_freq_ghz(freq);
        let (sim_s, toks) = serve_trace(cfg, model, 6)?;
        let per_tok = sim_s / toks;
        let b = *base.get_or_insert(sim_s);
        println!(
            "{:<10} {:>11.2} ms {:>11.2} us {:>9.3}x",
            format!("{} MHz", (freq * 1000.0) as u32),
            sim_s * 1e3,
            per_tok * 1e6,
            sim_s / b
        );
    }
    println!("\npaper Fig. 12: scaling 1 GHz -> 100 MHz costs at most ~20% latency —");
    println!("the ASIC is not the bottleneck, so edge deployments can clock it down.");
    Ok(())
}
