//! Quickstart: map a GPT model onto the PIM-GPT system, simulate a short
//! generation and print the headline metrics.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pim_gpt::config::HwConfig;
use pim_gpt::energy::SystemEnergy;
use pim_gpt::model::gpt::by_name;
use pim_gpt::sim::Simulator;

fn main() -> anyhow::Result<()> {
    // 1. Pick a model and the paper's Table-I hardware.
    let model = by_name("gpt2-small").unwrap();
    let cfg = HwConfig::paper_baseline();
    println!("model: {} ({:.0}M params)", model.name, model.n_params() as f64 / 1e6);
    println!(
        "hardware: {} channels x {} banks, {}-lane MACs, {} KB ASIC SRAM",
        cfg.gddr6.channels, cfg.gddr6.banks_per_channel, cfg.pim.mac_lanes, cfg.asic.sram_kb
    );

    // 2. Build the simulator — this runs the Algorithm-3 mapper: weights
    //    are head-concatenated and spread over all 128 banks, KV regions
    //    are reserved per layer.
    let mut sim = Simulator::new(&model, &cfg)?;
    println!(
        "mapping: peak bank fill {:.1}%, imbalance {} rows",
        100.0 * sim.mapping.fill,
        sim.mapping.imbalance_rows
    );

    // 3. Generate 64 tokens (each step: compile the decode graph to a
    //    PIM/ASIC instruction stream, execute it clock-cycle accurately).
    let tokens = 64;
    sim.generate(tokens)?;
    sim.finalize_stats();

    // 4. Report.
    let secs = sim.stats.seconds(cfg.gddr6.freq_ghz);
    let energy = SystemEnergy::from_sim(&sim);
    println!("\nsimulated {} tokens:", tokens);
    println!("  latency    : {:.1} us/token", secs * 1e6 / tokens as f64);
    println!("  energy     : {:.2} mJ/token", energy.total_j() * 1e3 / tokens as f64);
    println!("  row hits   : {:.2}%", 100.0 * sim.stats.row_hit_rate());
    println!("  vmm share  : {:.1}%", 100.0 * sim.stats.vmm_fraction());
    println!(
        "  PIM<->ASIC : {:.2} MB moved ({:.0}x less than a processor-centric system)",
        sim.stats.bytes_moved() as f64 / 1e6,
        (model.weight_bytes() * tokens) as f64 / sim.stats.bytes_moved() as f64
    );
    Ok(())
}
