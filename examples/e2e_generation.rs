//! End-to-end driver: all three layers composed on a real workload.
//!
//! Loads the `gpt-mini` functional artifact (JAX/Pallas decode step,
//! AOT-lowered to HLO text by `make artifacts`, executed through the
//! PJRT CPU client), serves a batch of generation requests through the
//! rust coordinator's FIFO server, and co-simulates the PIM-GPT timing
//! model — reporting functional throughput (wall clock), simulated
//! hardware latency/energy, and the generated tokens.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_generation
//! ```
//!
//! The run recorded in EXPERIMENTS.md §E2E comes from this binary.

use std::path::PathBuf;

use pim_gpt::config::HwConfig;
use pim_gpt::coordinator::{PimGptSystem, Request, Server};

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "gpt-mini".to_string());
    let dir = PathBuf::from("artifacts");
    if !dir.join(format!("{model}.meta.json")).exists() {
        anyhow::bail!("artifact '{model}' not found — run `make artifacts` first");
    }
    let cfg = HwConfig::paper_baseline();

    println!("== PIM-GPT end-to-end: functional decode + timing co-simulation ==");
    let cfg2 = cfg.clone();
    let m2 = model.clone();
    let mut server = Server::start(move || PimGptSystem::with_artifact(&m2, &dir, &cfg2));

    // A small trace of requests: varied prompts and lengths.
    let prompts: Vec<(Vec<i32>, usize)> = (0..12)
        .map(|i| {
            let prompt: Vec<i32> = (1..=(3 + i % 5) as i32).collect();
            (prompt, 16 + 4 * (i % 3) as usize)
        })
        .collect();
    let n_req = prompts.len() as u64;

    let wall0 = std::time::Instant::now();
    for (id, (prompt, n_new)) in prompts.into_iter().enumerate() {
        server.submit(Request { id: id as u64, prompt, n_new, arrival_cycle: 0 })?;
    }
    let mut sim_total = 0.0;
    let mut tok_total = 0usize;
    for _ in 0..n_req {
        let r = server.recv()?;
        if let Some(e) = r.error {
            println!("req {:>2}: ERROR {e}", r.id);
            continue;
        }
        sim_total += r.sim_seconds;
        tok_total += r.tokens.len();
        println!(
            "req {:>2}: {:>2} tokens  sim {:>8.1} us ({:>5.2} us/tok)  wall {:>6.1} ms  out: {:?}",
            r.id,
            r.tokens.len(),
            r.sim_seconds * 1e6,
            r.sim_seconds * 1e6 / r.tokens.len() as f64,
            r.wall_seconds * 1e3,
            &r.tokens[..r.tokens.len().min(10)],
        );
    }
    let wall = wall0.elapsed().as_secs_f64();
    let metrics = server.shutdown();

    println!("\n== summary ==");
    println!("requests            : {} ({} failed)", metrics.requests, metrics.failed);
    println!("tokens generated    : {tok_total}");
    println!("functional wall     : {:.2} s ({:.1} tok/s real numerics on CPU PJRT)", wall, tok_total as f64 / wall);
    println!(
        "simulated PIM-GPT   : {:.2} ms total ({:.0} tok/s on the accelerator)",
        sim_total * 1e3,
        tok_total as f64 / sim_total
    );
    println!(
        "speedup vs wall     : {:.0}x (simulated hardware vs CPU functional execution)",
        wall / sim_total
    );
    Ok(())
}
