//! Regenerate every table and figure of the paper's evaluation section.
//!
//! ```bash
//! cargo run --release --example paper_figures            # quick (64 tokens)
//! cargo run --release --example paper_figures -- 1024    # paper scale
//! ```
//!
//! Paper targets are embedded in each title; EXPERIMENTS.md records the
//! paper-vs-measured comparison produced by this binary.

use pim_gpt::config::HwConfig;
use pim_gpt::report;

fn main() -> anyhow::Result<()> {
    let tokens: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let sweep_tokens = tokens.min(64); // sensitivity sweeps re-run 8 models x points

    let mut reports = vec![
        report::fig1_model_zoo(),
        report::table1_config(&HwConfig::paper_baseline()),
        report::fig8_9_speedup_energy(tokens)?,
        report::fig10_breakdown(tokens)?,
        report::fig11_locality(tokens)?,
        report::fig12_asic_freq(sweep_tokens)?,
        report::fig13_bandwidth(sweep_tokens)?,
    ];
    if tokens >= 512 {
        reports.push(report::fig14_long_token(&[1024, 2048, 4096, 8096])?);
    } else {
        reports.push(report::fig14_long_token(&[128, 256, 512, 1024])?);
    }
    reports.push(report::fig15_scalability(sweep_tokens)?);
    reports.push(report::table2_comparison(tokens)?);

    for r in &reports {
        println!("{}\n{}", r.title, r.rendered);
    }
    println!("(regenerated {} experiments at {} tokens)", reports.len(), tokens);
    Ok(())
}
