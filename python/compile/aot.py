"""AOT compile path: lower the L2 decode step to HLO *text* artifacts.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` —
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
image's xla_extension 0.5.1 (behind the published ``xla`` 0.1.6 crate)
rejects; the text parser reassigns ids and round-trips cleanly.

Per functional model this emits into ``artifacts/``:

* ``<name>.hlo.txt``      — the decode step (token, pos, kc, vc, *params)
* ``<name>.weights.bin``  — little-endian f32 dump of every parameter, in
                            ``model.PARAM_NAMES`` order, contiguous
* ``<name>.meta.json``    — input signature (names/shapes/dtypes/offsets)
                            the rust runtime uses to build literals

Run via ``make artifacts`` (no-op when outputs are newer than inputs).
Python never runs after this step.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import FUNC_CONFIGS
from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(name: str, seed: int = 0):
    cfg = FUNC_CONFIGS[name]
    params = M.init_params(cfg, seed=seed)
    kc, vc = M.empty_caches(cfg)
    token = jnp.zeros((1,), jnp.int32)
    pos = jnp.zeros((1,), jnp.int32)
    flat = [params[n] for n in M.PARAM_NAMES]
    fn = M.aot_decode_fn(cfg)
    lowered = jax.jit(fn).lower(token, pos, kc, vc, *flat)
    return cfg, params, lowered


def emit(name: str, outdir: str, seed: int = 0) -> dict:
    cfg, params, lowered = lower_model(name, seed)
    os.makedirs(outdir, exist_ok=True)

    hlo_path = os.path.join(outdir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(to_hlo_text(lowered))

    # Weight blob + metadata describing the artifact's input signature.
    weights_path = os.path.join(outdir, f"{name}.weights.bin")
    inputs, offset = [], 0
    inputs.append({"name": "token", "shape": [1], "dtype": "i32", "kind": "token"})
    inputs.append({"name": "pos", "shape": [1], "dtype": "i32", "kind": "pos"})
    cache_shape = [cfg.n_layer, cfg.max_seq, cfg.d_model]
    inputs.append({"name": "k_cache", "shape": cache_shape, "dtype": "f32",
                   "kind": "cache"})
    inputs.append({"name": "v_cache", "shape": cache_shape, "dtype": "f32",
                   "kind": "cache"})
    with open(weights_path, "wb") as f:
        for pname in M.PARAM_NAMES:
            arr = np.asarray(params[pname], dtype="<f4")
            f.write(arr.tobytes(order="C"))
            inputs.append({
                "name": pname, "shape": list(arr.shape), "dtype": "f32",
                "kind": "param", "offset": offset, "nbytes": arr.nbytes,
            })
            offset += arr.nbytes

    cache_elems = cfg.n_layer * cfg.max_seq * cfg.d_model
    meta = {
        "name": name,
        "config": {
            "n_layer": cfg.n_layer, "d_model": cfg.d_model,
            "n_head": cfg.n_head, "vocab": cfg.vocab,
            "max_seq": cfg.max_seq,
        },
        # Single flat f32 output (see model.aot_decode_fn): the rust
        # runtime splits it at these element counts.
        "outputs": {"kind": "flat",
                    "splits": [["logits", cfg.vocab],
                               ["k_cache", cache_elems],
                               ["v_cache", cache_elems]]},
        "inputs": inputs,
        "weights_bin": os.path.basename(weights_path),
        "hlo": os.path.basename(hlo_path),
        "seed": seed,
    }
    meta_path = os.path.join(outdir, f"{name}.meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--models", nargs="*", default=list(FUNC_CONFIGS),
                    help=f"functional models to lower (default: all of "
                         f"{list(FUNC_CONFIGS)})")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    for name in args.models:
        meta = emit(name, args.out, seed=args.seed)
        print(f"wrote {meta['hlo']} + weights ({name})")


if __name__ == "__main__":
    main()
