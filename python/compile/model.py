"""L2: GPT decode step in JAX, calling the L1 Pallas kernels.

This is the *functional* twin of the hardware dataflow the rust simulator
times: a decoder-only (GPT-2 style, pre-LN) transformer that processes one
token per step against a KV cache, exactly like PIM-GPT generates tokens.

* All weight-matrix products go through ``kernels.pim_vmm`` (bank-tiled
  Pallas VMM — the PIM side of the paper's hybrid).
* All non-VMM math (layernorm, softmax, GELU, residual adds) uses the
  ASIC approximation algorithms from ``kernels.asic_ops`` (the ASIC side).

``decode_step`` is AOT-lowered once by ``aot.py`` into an HLO-text
artifact; the rust coordinator loads it via PJRT and calls it per token.
Python never runs at serving time.

``reference_decode_step`` is the exact-math oracle (jnp matmul, true
softmax/LN/GELU) used by pytest to bound the approximation error of the
whole step.
"""

import functools

import jax
import jax.numpy as jnp

from .configs import GptConfig
from .kernels import asic_ops
from .kernels.pim_vmm import pim_vmm, pim_vmm_bias
from .kernels import ref as kref

# Deterministic parameter order for the AOT artifact's input signature.
# rust reads the same order out of <name>.meta.json.
PARAM_NAMES = [
    "wte", "wpe",
    "ln1_g", "ln1_b", "wqkv", "bqkv", "wo", "bo",
    "ln2_g", "ln2_b", "w1", "b1", "w2", "b2",
    "lnf_g", "lnf_b",
]


def param_shapes(cfg: GptConfig):
    """Shape of every parameter array, keyed by PARAM_NAMES entries."""
    L, D, F, V, T = cfg.n_layer, cfg.d_model, cfg.d_ff, cfg.vocab, cfg.max_seq
    return {
        "wte": (V, D), "wpe": (T, D),
        "ln1_g": (L, D), "ln1_b": (L, D),
        "wqkv": (L, D, 3 * D), "bqkv": (L, 3 * D),
        "wo": (L, D, D), "bo": (L, D),
        "ln2_g": (L, D), "ln2_b": (L, D),
        "w1": (L, D, F), "b1": (L, F),
        "w2": (L, F, D), "b2": (L, D),
        "lnf_g": (D,), "lnf_b": (D,),
    }


def init_params(cfg: GptConfig, seed: int = 0, dtype=jnp.float32):
    """GPT-2-style init (N(0, 0.02) weights, unit layernorm gains)."""
    shapes = param_shapes(cfg)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(PARAM_NAMES))
    params = {}
    for name, key in zip(PARAM_NAMES, keys):
        shp = shapes[name]
        if name.endswith("_g"):
            params[name] = jnp.ones(shp, dtype)
        elif name.endswith("_b") or name.startswith("b"):
            params[name] = jnp.zeros(shp, dtype)
        else:
            params[name] = (0.02 * jax.random.normal(key, shp)).astype(dtype)
    return params


def _attention(cfg, q, k_cache_l, v_cache_l, pos, *, exact=False):
    """Single-token multi-head attention against one layer's KV cache.

    q: (D,); k_cache_l/v_cache_l: (T, D); pos: i32 scalar (current index).
    """
    H, Dh, T = cfg.n_head, cfg.d_head, cfg.max_seq
    qh = q.reshape(H, Dh).astype(jnp.float32)
    kh = k_cache_l.reshape(T, H, Dh).astype(jnp.float32)
    vh = v_cache_l.reshape(T, H, Dh).astype(jnp.float32)
    # Attention scores: per-head q . k_t, exactly the row-major K-cache MAC
    # the PIM banks execute (Fig. 7a).
    scores = jnp.einsum("hd,thd->ht", qh, kh) / jnp.sqrt(jnp.float32(Dh))
    mask = (jnp.arange(T) <= pos)[None, :]  # (1, T) -> broadcast over heads
    if exact:
        probs = kref.softmax_ref(scores, mask)
    else:
        probs = asic_ops.softmax_asic(scores, mask)
    # scores @ V: the column-major V-cache MAC (Fig. 7b).
    out = jnp.einsum("ht,thd->hd", probs, vh)
    return out.reshape(cfg.d_model)


def _block(cfg, params, l, x, k_cache, v_cache, pos, *, exact, interpret):
    """One transformer block (pre-LN). Returns (x, k_cache, v_cache)."""
    ln = kref.layernorm_ref if exact else asic_ops.layernorm_asic
    gelu = kref.gelu_ref if exact else asic_ops.gelu_asic
    if exact:
        mm = lambda v, w, b: kref.vmm_ref(v, w).astype(jnp.float32) + b
    else:
        mm = functools.partial(pim_vmm_bias, interpret=interpret)

    D = cfg.d_model
    h = ln(x, params["ln1_g"][l], params["ln1_b"][l])
    qkv = mm(h.astype(x.dtype), params["wqkv"][l], params["bqkv"][l])
    qkv = qkv.astype(jnp.float32)
    q, k, v = qkv[:D], qkv[D:2 * D], qkv[2 * D:]

    # Write back k (row-major) and v (column-major in HW; layout here is
    # logical) into the reserved cache rows for this position.
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype)[None, None, :], (l, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype)[None, None, :], (l, pos, 0))

    attn = _attention(cfg, q, k_cache[l], v_cache[l], pos, exact=exact)
    proj = mm(attn.astype(x.dtype), params["wo"][l], params["bo"][l])
    x = x + proj.astype(jnp.float32)

    h2 = ln(x, params["ln2_g"][l], params["ln2_b"][l])
    f = mm(h2.astype(x.dtype), params["w1"][l], params["b1"][l])
    f = gelu(f)
    out = mm(f.astype(x.dtype), params["w2"][l], params["b2"][l])
    return x + out.astype(jnp.float32), k_cache, v_cache


def decode_step(cfg: GptConfig, params, token, pos, k_cache, v_cache,
                *, exact: bool = False, interpret: bool = True):
    """Decode one token.

    token: i32[1]; pos: i32[1]; caches: f32[L, T, D].
    Returns (logits f32[vocab], k_cache, v_cache).
    """
    tok = token[0]
    p = pos[0]
    x = (jnp.take(params["wte"], tok, axis=0).astype(jnp.float32)
         + jnp.take(params["wpe"], p, axis=0).astype(jnp.float32))

    for l in range(cfg.n_layer):
        x, k_cache, v_cache = _block(cfg, params, l, x, k_cache, v_cache, p,
                                     exact=exact, interpret=interpret)

    if exact:
        x = kref.layernorm_ref(x, params["lnf_g"], params["lnf_b"])
        logits = kref.vmm_ref(x, params["wte"].T.astype(jnp.float32))
    else:
        x = asic_ops.layernorm_asic(x, params["lnf_g"], params["lnf_b"])
        logits = pim_vmm(x.astype(params["wte"].dtype),
                         jnp.transpose(params["wte"]),
                         interpret=interpret).astype(jnp.float32)
    return logits, k_cache, v_cache


def reference_decode_step(cfg, params, token, pos, k_cache, v_cache):
    """Exact-math oracle for ``decode_step`` (no Pallas, no approximations)."""
    return decode_step(cfg, params, token, pos, k_cache, v_cache, exact=True)


def empty_caches(cfg: GptConfig, dtype=jnp.float32):
    shape = (cfg.n_layer, cfg.max_seq, cfg.d_model)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def flat_decode_fn(cfg: GptConfig, *, exact=False, interpret=True):
    """Decode step with a flat positional signature for AOT lowering:

    f(token, pos, k_cache, v_cache, *params_in_PARAM_NAMES_order)
    """
    def fn(token, pos, k_cache, v_cache, *flat_params):
        params = dict(zip(PARAM_NAMES, flat_params))
        return decode_step(cfg, params, token, pos, k_cache, v_cache,
                           exact=exact, interpret=interpret)
    return fn


def aot_decode_fn(cfg: GptConfig, *, interpret=True):
    """AOT entrypoint: same as ``flat_decode_fn`` but returns ONE flat
    f32 vector ``concat(logits, k_cache.ravel(), v_cache.ravel())``.

    Rationale: the rust side runs on the xla crate's PJRT CPU client,
    whose ``to_literal_sync`` cannot convert multi-element tuple buffers;
    a single array (wrapped by lowering into a 1-tuple) round-trips
    cleanly. The rust runtime re-splits using the sizes in meta.json.
    """
    base = flat_decode_fn(cfg, interpret=interpret)

    def fn(token, pos, k_cache, v_cache, *flat_params):
        logits, kc, vc = base(token, pos, k_cache, v_cache, *flat_params)
        return jnp.concatenate([
            logits.astype(jnp.float32).reshape(-1),
            kc.astype(jnp.float32).reshape(-1),
            vc.astype(jnp.float32).reshape(-1),
        ])
    return fn


def generate(cfg, params, prompt, n_new, *, exact=False, interpret=True):
    """Pure-python greedy generation (test/debug path; rust owns serving)."""
    step = jax.jit(functools.partial(decode_step, cfg,
                                     exact=exact, interpret=interpret))
    k_cache, v_cache = empty_caches(cfg)
    toks = list(prompt)
    logits = None
    for i, t in enumerate(toks):
        logits, k_cache, v_cache = step(
            params, jnp.array([t], jnp.int32), jnp.array([i], jnp.int32),
            k_cache, v_cache)
    for i in range(len(prompt), len(prompt) + n_new):
        nxt = int(jnp.argmax(logits))
        toks.append(nxt)
        if i + 1 >= cfg.max_seq:
            break
        logits, k_cache, v_cache = step(
            params, jnp.array([nxt], jnp.int32), jnp.array([i], jnp.int32),
            k_cache, v_cache)
    return toks
