"""Model configurations.

Two families:

* ``FUNC_CONFIGS`` — tiny functional models that are AOT-lowered to HLO
  artifacts and actually executed by the rust coordinator (L3) through PJRT.
  Weights are synthetic (seeded), since no checkpoints are available offline;
  timing behaviour in the simulator depends only on shapes.

* ``PAPER_CONFIGS`` — the 8 GPT-2/GPT-3 model shapes evaluated in the paper
  (Fig. 8-15). These are mirrored on the rust side (``model::gpt``); they are
  kept here so python tests can cross-check parameter/FLOP counts (Fig. 1).
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class GptConfig:
    name: str
    n_layer: int
    d_model: int
    n_head: int
    vocab: int
    max_seq: int

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_head

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    def n_params(self) -> int:
        """Parameter count (weights + biases + layernorms + embeddings)."""
        d, L = self.d_model, self.n_layer
        per_layer = (
            d * 3 * d + 3 * d          # qkv
            + d * d + d                # attn proj
            + d * self.d_ff + self.d_ff  # fc1
            + self.d_ff * d + d        # fc2
            + 4 * d                    # 2x layernorm (gamma, beta)
        )
        emb = self.vocab * d + self.max_seq * d
        return L * per_layer + emb + 2 * d  # final layernorm

    def flops_per_token(self, seq_len: int) -> int:
        """MAC-dominated op count for decoding one token at context length
        ``seq_len`` (multiply+add counted as 2 ops), incl. the LM head."""
        d, L = self.d_model, self.n_layer
        per_layer = 2 * (
            d * 3 * d        # qkv
            + d * seq_len    # q @ K^T  (all heads combined)
            + seq_len * d    # scores @ V
            + d * d          # attn proj
            + d * self.d_ff  # fc1
            + self.d_ff * d  # fc2
        )
        return L * per_layer + 2 * d * self.vocab  # lm head


# Functional (executable) configs — small on purpose: these run per-token on
# the CPU PJRT client inside the rust serving loop.
FUNC_CONFIGS = {
    "gpt-nano": GptConfig("gpt-nano", n_layer=2, d_model=128, n_head=4,
                          vocab=512, max_seq=128),
    "gpt-mini": GptConfig("gpt-mini", n_layer=4, d_model=256, n_head=8,
                          vocab=2048, max_seq=256),
}

# The 8 models of the paper's evaluation (Table of §V.A, Fig. 8/9).
PAPER_CONFIGS = {
    "gpt2-small":  GptConfig("gpt2-small",  12, 768,  12, 50257, 1024),
    "gpt2-medium": GptConfig("gpt2-medium", 24, 1024, 16, 50257, 1024),
    "gpt2-large":  GptConfig("gpt2-large",  36, 1280, 20, 50257, 1024),
    "gpt2-xl":     GptConfig("gpt2-xl",     48, 1600, 25, 50257, 1024),
    "gpt3-small":  GptConfig("gpt3-small",  12, 768,  12, 50257, 2048),
    "gpt3-medium": GptConfig("gpt3-medium", 24, 1024, 16, 50257, 2048),
    "gpt3-large":  GptConfig("gpt3-large",  24, 1536, 16, 50257, 2048),
    "gpt3-xl":     GptConfig("gpt3-xl",     24, 2048, 24, 50257, 2048),
}
