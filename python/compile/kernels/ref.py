"""Pure-jnp oracles for the L1 kernels.

These use exact math (``jnp.exp``, ``jnp.tanh``, true division, ``sqrt``)
and are the correctness references both for the Pallas kernels and — via
mirrored unit tests — for the rust ``arith`` module that models the ASIC
computation engines.
"""

import jax.numpy as jnp


def vmm_ref(x, w):
    """y = x @ W with f32 accumulation. x: (d_in,), w: (d_in, d_out)."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)).astype(x.dtype)


def softmax_ref(x, mask=None):
    """Numerically-stable masked softmax over the last axis."""
    x = x.astype(jnp.float32)
    if mask is not None:
        x = jnp.where(mask, x, -jnp.inf)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    if mask is not None:
        e = jnp.where(mask, e, 0.0)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def layernorm_ref(x, gamma, beta, eps=1e-5):
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) / jnp.sqrt(var + eps)
    return y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)


def gelu_ref(x):
    """tanh-approximated GELU (the paper's Eq. 4 target form, exact tanh)."""
    x = x.astype(jnp.float32)
    return 0.5 * x * (1.0 + jnp.tanh(jnp.sqrt(2.0 / jnp.pi)
                                     * (x + 0.044715 * x ** 3)))


def reciprocal_ref(x):
    return 1.0 / x.astype(jnp.float32)


def rsqrt_ref(x):
    return 1.0 / jnp.sqrt(x.astype(jnp.float32))


def exp_ref(x):
    return jnp.exp(x.astype(jnp.float32))


def tanh_ref(x):
    return jnp.tanh(x.astype(jnp.float32))
