"""Bank-tiled Pallas VMM kernel — the L1 compute hot-spot.

Computes ``y = x @ W`` for a single token vector, partitioned exactly the
way the PIM-GPT mapping compiler (rust ``mapping`` module) partitions a
weight matrix over the DRAM hierarchy:

* the grid has one step per (channel, bank) pair — 8 x 16 = 128 MAC units
  in the paper's baseline configuration;
* each grid step owns a contiguous slice of output columns (the rust
  mapper distributes columns of the head-concatenated matrix evenly across
  channels, then banks — Fig. 6b);
* inside a step, the 16-lane MAC pipeline is modeled literally: a
  ``fori_loop`` consumes 16 input elements x 16-wide weight rows per
  iteration and accumulates into f32 (the bank adder tree);
* the input vector block is broadcast to every grid step — the channel
  global-buffer broadcast.

On a real TPU the same kernel would tile for the MXU instead (see
DESIGN.md §Hardware-Adaptation); ``interpret=True`` is mandatory on the
CPU PJRT backend.

``python/tests/test_kernel.py`` sweeps shapes/dtypes with hypothesis and
asserts allclose against ``ref.vmm_ref``; a dedicated test checks that the
kernel's column partition agrees block-for-block with the rust mapper's
(same formula, mirrored in ``mapping::weight_map`` unit tests).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MAC_LANES = 16      # multipliers per bank MAC unit (paper Fig. 4c)
N_CHANNELS = 8      # GDDR6 channels (Table I)
N_BANKS = 16        # banks per channel (Table I)


def pad_to(n: int, mult: int) -> int:
    """Smallest multiple of ``mult`` that is >= n."""
    return (n + mult - 1) // mult * mult


def bank_partition(d_out: int, n_units: int):
    """Columns-per-unit of the padded even partition.

    Mirrors rust ``mapping::weight_map::columns_per_unit`` — keep in sync.
    """
    return pad_to(d_out, n_units) // n_units


def _mac_kernel(x_ref, w_ref, o_ref, *, lanes: int):
    """One bank's MAC pipeline over its column slice."""
    d_in = x_ref.shape[0]
    cols = o_ref.shape[0]
    acc0 = jnp.zeros((cols,), jnp.float32)

    def body(k, acc):
        # 16 input values from the global buffer ...
        xv = x_ref[pl.ds(k * lanes, lanes)].astype(jnp.float32)
        # ... MACed against 16 row-contiguous weight rows from the open row.
        wv = w_ref[pl.ds(k * lanes, lanes), :].astype(jnp.float32)
        return acc + xv @ wv

    acc = jax.lax.fori_loop(0, d_in // lanes, body, acc0)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_channels", "n_banks", "lanes", "interpret"),
)
def pim_vmm(x, w, *, n_channels=N_CHANNELS, n_banks=N_BANKS,
            lanes=MAC_LANES, interpret=True):
    """y = x @ W, bank-tiled. x: (d_in,), w: (d_in, d_out) -> (d_out,).

    Output dtype follows x. Accumulation is f32 (the adder tree operates at
    full precision before the result vector is sent to the ASIC).
    """
    d_in, d_out = w.shape
    assert x.shape == (d_in,), (x.shape, w.shape)
    n_units = n_channels * n_banks

    d_in_p = pad_to(d_in, lanes)
    cols_pu = bank_partition(d_out, n_units)
    d_out_p = cols_pu * n_units

    if d_in_p != d_in:
        x = jnp.pad(x, (0, d_in_p - d_in))
        w = jnp.pad(w, ((0, d_in_p - d_in), (0, 0)))
    if d_out_p != d_out:
        w = jnp.pad(w, ((0, 0), (0, d_out_p - d_out)))

    y = pl.pallas_call(
        functools.partial(_mac_kernel, lanes=lanes),
        grid=(n_units,),
        in_specs=[
            # Global-buffer broadcast: every unit sees the whole vector.
            pl.BlockSpec((d_in_p,), lambda i: (0,)),
            # Each unit owns a contiguous column slice of the matrix.
            pl.BlockSpec((d_in_p, cols_pu), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((cols_pu,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d_out_p,), x.dtype),
        interpret=interpret,
    )(x, w)
    return y[:d_out]


def pim_vmm_bias(x, w, b, **kw):
    """VMM + bias add (bias addition happens on the ASIC in hardware)."""
    return (pim_vmm(x, w, **kw).astype(jnp.float32)
            + b.astype(jnp.float32)).astype(x.dtype)
