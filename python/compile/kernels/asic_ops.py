"""ASIC computation-engine ops, add/mul-only approximation algorithms.

The PIM-GPT ASIC implements every non-VMM function with adders and
multipliers only (paper §III.D):

* ``exp``  — range-reduced Taylor series, 6 terms (paper: "Taylor series
  approximation with the first six items"). Raw Taylor-6 diverges for
  x < -4, so like any fixed-precision hardware implementation we first
  split x = k·ln2 + r with r ∈ [-ln2/2, ln2/2] (one multiply + round) and
  reconstruct 2^k by integer exponent assembly (a bit-pack, the same
  hardware primitive Algorithm 2 already requires).
* ``tanh`` — via exp identity tanh(x) = 1 - 2/(e^{2x}+1), reusing the
  Taylor exp and the Newton-Raphson reciprocal.
* ``reciprocal`` — paper Algorithm 1 (Newton-Raphson division): scale D
  into [0.5, 1) by exponent subtraction, X0 = 48/17 - 32/17·D', three
  iterations X = X + X·(1 - D'X), rescale.
* ``rsqrt`` — paper Algorithm 2 (Quake fast inverse square root): bit
  trick 0x5f3759df - (L >> 1) followed by two Newton iterations
  X = X·(1.5 - 0.5·D·X²).

All functions are jax-traceable, work elementwise on f32/bf16 arrays, and
lower into the same HLO as the rest of the model. They are exercised both
directly (pytest error bounds vs kernels.ref) and inside the Pallas
kernels below.

The rust ``arith`` module mirrors these algorithms bit-for-bit on scalars;
``python/tests/test_asic_ops.py`` pins a table of golden values that the
rust unit tests replicate, keeping the two implementations locked.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

LN2 = 0.6931471805599453
INV_LN2 = 1.4426950408889634

# Reciprocal of factorials for the 6-term Taylor series of exp:
# 1 + x + x^2/2 + x^3/6 + x^4/24 + x^5/120
_EXP_TAYLOR_COEF = (1.0, 1.0, 0.5, 1.0 / 6.0, 1.0 / 24.0, 1.0 / 120.0)


def _as_f32(x):
    return jnp.asarray(x).astype(jnp.float32)


def exp_taylor6(x):
    """Range-reduced 6-term Taylor exp. Add/mul + exponent assembly only."""
    x = _as_f32(x)
    # Clamp to the representable range so 2^k stays a normal f32 (the ASIC
    # saturates likewise); softmax inputs are max-subtracted so x <= 0.
    x = jnp.clip(x, -87.0, 87.0)
    k = jnp.round(x * INV_LN2)
    r = x - k * LN2
    # Horner evaluation of the Taylor polynomial (5 mul + 5 add).
    p = _EXP_TAYLOR_COEF[5]
    for c in _EXP_TAYLOR_COEF[4::-1]:
        p = p * r + c
    # 2^k by assembling the exponent field: bits = (k + 127) << 23.
    biased = (k + 127.0).astype(jnp.int32)
    biased = jnp.clip(biased, 1, 254)
    two_k = jax.lax.bitcast_convert_type(biased << 23, jnp.float32)
    return p * two_k


def reciprocal_nr(d, iters=3):
    """Paper Algorithm 1: Newton-Raphson division (reciprocal of d).

    d is scaled into [0.5, 1) by exponent subtraction; X0 = 48/17 - 32/17 d';
    ``iters`` NR steps double the correct bits each time (3 steps ≥ f32).
    Handles negative inputs via sign restore; d must be non-zero & finite.
    """
    d = _as_f32(d)
    sign = jnp.where(d < 0, -1.0, 1.0).astype(jnp.float32)
    mag = d * sign
    bits = jax.lax.bitcast_convert_type(mag, jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127  # unbiased exponent, mag = m * 2^e
    # d' = mag / 2^(e+1) in [0.5, 1): subtract e+1 from the exponent field.
    dp = jax.lax.bitcast_convert_type(bits - ((e + 1) << 23), jnp.float32)
    x = 48.0 / 17.0 - (32.0 / 17.0) * dp
    for _ in range(iters):
        x = x + x * (1.0 - dp * x)
    # Rescale: 1/mag = x / 2^(e+1), again via exponent arithmetic.
    xbits = jax.lax.bitcast_convert_type(x, jnp.int32)
    out = jax.lax.bitcast_convert_type(xbits - ((e + 1) << 23), jnp.float32)
    return out * sign


def rsqrt_fast(d, iters=2):
    """Paper Algorithm 2: Quake fast inverse square root, two NR steps."""
    d = _as_f32(d)
    half = 0.5 * d
    bits = jax.lax.bitcast_convert_type(d, jnp.int32)
    magic = jnp.int32(0x5F3759DF)
    x = jax.lax.bitcast_convert_type(magic - (bits >> 1), jnp.float32)
    for _ in range(iters):
        x = x * (1.5 - half * x * x)
    return x


def tanh_exp(x):
    """tanh via the exp identity (reuses Taylor exp + NR reciprocal)."""
    x = _as_f32(x)
    # tanh saturates: |x| > 9 => ±1 within bf16. Clamp keeps exp in range.
    xc = jnp.clip(x, -9.0, 9.0)
    e2x = exp_taylor6(2.0 * xc)
    return 1.0 - 2.0 * reciprocal_nr(e2x + 1.0)


def softmax_asic(x, mask=None):
    """Masked softmax with ASIC arithmetic (max-subtract, Taylor exp,
    adder-tree sum, NR reciprocal). Last-axis reduction."""
    x = _as_f32(x)
    neg = jnp.float32(-1e30)
    if mask is not None:
        x = jnp.where(mask, x, neg)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = exp_taylor6(x - m)
    if mask is not None:
        e = jnp.where(mask, e, 0.0)
    s = jnp.sum(e, axis=-1, keepdims=True)
    return e * reciprocal_nr(s)


def layernorm_asic(x, gamma, beta, eps=1e-5):
    """LayerNorm with ASIC arithmetic: mean/var via adder tree + constant
    1/n multiplies, then fast inverse sqrt (Algorithm 2)."""
    x = _as_f32(x)
    n = x.shape[-1]
    inv_n = jnp.float32(1.0 / n)  # constant, precomputed at compile time
    mu = jnp.sum(x, axis=-1, keepdims=True) * inv_n
    var = jnp.sum((x - mu) * (x - mu), axis=-1, keepdims=True) * inv_n
    y = (x - mu) * rsqrt_fast(var + eps)
    return y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)


def gelu_asic(x):
    """Paper Eq. 4 GELU with the ASIC tanh."""
    x = _as_f32(x)
    c = 0.7978845608028654  # sqrt(2/pi)
    return 0.5 * x * (1.0 + tanh_exp(c * (x + 0.044715 * x * x * x)))


# ---------------------------------------------------------------------------
# Pallas-wrapped kernels (interpret=True): same math staged as explicit
# kernels so the ASIC ops can be unit-benchmarked/tested at the kernel level.
# ---------------------------------------------------------------------------

def _softmax_kernel(x_ref, o_ref):
    o_ref[...] = softmax_asic(x_ref[...]).astype(o_ref.dtype)


def softmax_kernel(x, interpret=True):
    """Pallas softmax over the last axis of a 1-D or 2-D array."""
    return pl.pallas_call(
        _softmax_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=interpret,
    )(x)


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref):
    o_ref[...] = layernorm_asic(x_ref[...], g_ref[...], b_ref[...]).astype(o_ref.dtype)


def layernorm_kernel(x, gamma, beta, interpret=True):
    return pl.pallas_call(
        _layernorm_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=interpret,
    )(x, gamma, beta)


def _gelu_kernel(x_ref, o_ref):
    o_ref[...] = gelu_asic(x_ref[...]).astype(o_ref.dtype)


def gelu_kernel(x, interpret=True):
    return pl.pallas_call(
        _gelu_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=interpret,
    )(x)


__all__ = [
    "exp_taylor6", "reciprocal_nr", "rsqrt_fast", "tanh_exp",
    "softmax_asic", "layernorm_asic", "gelu_asic",
    "softmax_kernel", "layernorm_kernel", "gelu_kernel", "ref",
]
