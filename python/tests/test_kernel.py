"""L1 Pallas VMM kernel vs the pure-jnp oracle — the core correctness
signal. Hypothesis sweeps shapes and dtypes; fixed tests pin the bank
partition layout against the rust mapper's formula."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pim_vmm as PV
from compile.kernels import ref as R


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _tol(dtype):
    # bf16 storage keeps ~8 bits of mantissa; accumulation is f32.
    return dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    d_in=st.integers(1, 300),
    d_out=st.integers(1, 300),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_vmm_matches_ref_shapes_dtypes(d_in, d_out, dtype, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = _rand(k1, (d_in,), dtype)
    w = _rand(k2, (d_in, d_out), dtype)
    y = PV.pim_vmm(x, w)
    yr = R.vmm_ref(x, w)
    assert y.shape == (d_out,)
    assert y.dtype == dtype
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


@pytest.mark.parametrize("d_in,d_out", [(16, 128), (100, 300), (1, 1),
                                        (768, 2304), (64, 257)])
def test_vmm_f32_exact_shapes(d_in, d_out):
    k1, k2 = jax.random.split(jax.random.PRNGKey(d_in * 7 + d_out))
    x = _rand(k1, (d_in,), jnp.float32)
    w = _rand(k2, (d_in, d_out), jnp.float32)
    np.testing.assert_allclose(PV.pim_vmm(x, w), R.vmm_ref(x, w),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("ch,banks", [(8, 16), (4, 16), (1, 1), (2, 4)])
def test_vmm_custom_geometry(ch, banks):
    """The kernel must be correct for any channel/bank partition (the
    Fig. 15b scalability sweep changes these)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = _rand(k1, (96,), jnp.float32)
    w = _rand(k2, (96, 200), jnp.float32)
    y = PV.pim_vmm(x, w, n_channels=ch, n_banks=banks)
    np.testing.assert_allclose(y, R.vmm_ref(x, w), rtol=1e-5, atol=1e-5)


def test_vmm_zero_input():
    w = jnp.ones((32, 64), jnp.float32)
    y = PV.pim_vmm(jnp.zeros((32,), jnp.float32), w)
    assert np.all(np.asarray(y) == 0.0)


def test_vmm_bias():
    k1, k2 = jax.random.split(jax.random.PRNGKey(9))
    x = _rand(k1, (48,), jnp.float32)
    w = _rand(k2, (48, 80), jnp.float32)
    b = jnp.arange(80, dtype=jnp.float32)
    np.testing.assert_allclose(PV.pim_vmm_bias(x, w, b),
                               R.vmm_ref(x, w) + b, rtol=1e-5, atol=1e-5)


def test_bank_partition_matches_rust_mapper():
    """Mirrors rust ``mapping::weight_map`` unit test `columns_per_unit`:
    the Pallas grid and the simulator must slice matrices identically."""
    cases = {
        # (d_out, n_units) -> cols_per_unit
        (2304, 128): 18,
        (768, 128): 6,
        (50257, 128): 393,
        (1, 128): 1,
        (129, 128): 2,
        (512, 8): 64,
    }
    for (d_out, n_units), want in cases.items():
        assert PV.bank_partition(d_out, n_units) == want, (d_out, n_units)


@settings(max_examples=20, deadline=None)
@given(d_out=st.integers(1, 10_000), n_units=st.integers(1, 512))
def test_bank_partition_properties(d_out, n_units):
    cols = PV.bank_partition(d_out, n_units)
    # Covers the matrix...
    assert cols * n_units >= d_out
    # ...with minimal padding (< one unit's worth of columns).
    assert (cols - 1) * n_units < d_out or cols == 1
