"""Hypothesis sweeps over the Pallas-wrapped kernels' shapes and dtypes —
the L1 coverage requirement: every kernel correct for arbitrary shapes,
both storage dtypes, against the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import asic_ops as A
from compile.kernels import pim_vmm as PV
from compile.kernels import ref as R


@settings(max_examples=15, deadline=None)
@given(rows=st.integers(1, 8), n=st.integers(2, 256),
       seed=st.integers(0, 2**31 - 1))
def test_softmax_kernel_shape_sweep(rows, n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, n)) * 3
    got = np.asarray(A.softmax_kernel(x))
    want = np.asarray(R.softmax_ref(x))
    np.testing.assert_allclose(got, want, atol=1e-5)
    np.testing.assert_allclose(got.sum(axis=-1), 1.0, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 512), seed=st.integers(0, 2**31 - 1))
def test_layernorm_kernel_shape_sweep(n, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (n,)) * 2 + 1
    g = 1.0 + 0.1 * jax.random.normal(k2, (n,))
    b = 0.1 * jax.random.normal(k3, (n,))
    np.testing.assert_allclose(np.asarray(A.layernorm_kernel(x, g, b)),
                               np.asarray(R.layernorm_ref(x, g, b)),
                               atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 1024), lo=st.floats(-8, 0), hi=st.floats(0, 8),
       seed=st.integers(0, 2**31 - 1))
def test_gelu_kernel_shape_sweep(n, lo, hi, seed):
    x = jax.random.uniform(jax.random.PRNGKey(seed), (n,),
                           minval=lo, maxval=hi)
    np.testing.assert_allclose(np.asarray(A.gelu_kernel(x)),
                               np.asarray(R.gelu_ref(x)), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(d_in=st.integers(1, 200), d_out=st.integers(1, 200),
       seed=st.integers(0, 2**31 - 1))
def test_vmm_bf16_storage_f32_accumulate(d_in, d_out, seed):
    """bf16 storage with f32 accumulation (the bank adder tree): error
    stays at bf16-input level, not bf16-accumulation level."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (d_in,)).astype(jnp.bfloat16)
    w = jax.random.normal(k2, (d_in, d_out)).astype(jnp.bfloat16)
    got = np.asarray(PV.pim_vmm(x, w), np.float32)
    want = np.asarray(
        jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)), np.float32)
    # rtol ~ bf16 eps * modest growth; a bf16 accumulator would be much worse
    tol = 0.02 * max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(got, want, atol=tol)


def test_vmm_kernel_vs_model_partition_consistency():
    """The kernel's grid partition and the rust mapper must agree on the
    per-unit column counts for all paper-model matrix shapes."""
    shapes = [(768, 2304), (1024, 3072), (1280, 3840), (1600, 4800),
              (1536, 4608), (2048, 6144), (768, 50257), (8192, 2048)]
    for d_in, d_out in shapes:
        cols = PV.bank_partition(d_out, 128)
        covered = sum(
            max(0, min((u + 1) * cols, d_out) - min(u * cols, d_out))
            for u in range(128)
        )
        assert covered == d_out, (d_in, d_out)
