"""AOT artifact emission: HLO text parses, the input signature in
meta.json matches the weight blob, and re-emission is deterministic."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.configs import FUNC_CONFIGS


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    meta = aot.emit("gpt-nano", out, seed=0)
    return out, meta


def test_hlo_text_well_formed(emitted):
    out, meta = emitted
    text = open(os.path.join(out, meta["hlo"])).read()
    assert "HloModule" in text
    assert "ENTRY" in text
    # Tuple-returning entry (rust unwraps with to_tuple)
    assert "(f32[" in text or "tuple" in text


def test_meta_matches_weight_blob(emitted):
    out, meta = emitted
    blob = os.path.getsize(os.path.join(out, meta["weights_bin"]))
    total = 0
    for inp in meta["inputs"]:
        if inp["kind"] == "param":
            n = int(np.prod(inp["shape"])) * 4
            assert inp["nbytes"] == n, inp
            assert inp["offset"] == total
            total += n
    assert total == blob


def test_meta_input_order(emitted):
    _, meta = emitted
    names = [i["name"] for i in meta["inputs"]]
    assert names[:4] == ["token", "pos", "k_cache", "v_cache"]
    assert names[4:] == M.PARAM_NAMES


def test_meta_config_roundtrip(emitted):
    _, meta = emitted
    cfg = FUNC_CONFIGS["gpt-nano"]
    assert meta["config"]["n_layer"] == cfg.n_layer
    assert meta["config"]["d_model"] == cfg.d_model
    assert meta["config"]["vocab"] == cfg.vocab


def test_emission_deterministic(tmp_path):
    a = aot.emit("gpt-nano", str(tmp_path / "a"), seed=0)
    b = aot.emit("gpt-nano", str(tmp_path / "b"), seed=0)
    wa = open(os.path.join(tmp_path / "a", a["weights_bin"]), "rb").read()
    wb = open(os.path.join(tmp_path / "b", b["weights_bin"]), "rb").read()
    assert wa == wb
    ha = open(os.path.join(tmp_path / "a", a["hlo"])).read()
    hb = open(os.path.join(tmp_path / "b", b["hlo"])).read()
    assert ha == hb


def test_weight_blob_reproduces_params(emitted):
    out, meta = emitted
    params = M.init_params(FUNC_CONFIGS["gpt-nano"], seed=0)
    blob = open(os.path.join(out, meta["weights_bin"]), "rb").read()
    for inp in meta["inputs"]:
        if inp["kind"] != "param":
            continue
        arr = np.frombuffer(blob, "<f4", count=int(np.prod(inp["shape"])),
                            offset=inp["offset"]).reshape(inp["shape"])
        np.testing.assert_array_equal(arr, np.asarray(params[inp["name"]]))
