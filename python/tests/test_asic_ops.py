"""ASIC approximation algorithms (paper §III.D, Algorithms 1-2) vs exact
math: error bounds over the operating ranges, golden values shared with
the rust ``arith`` module, and the Pallas-wrapped kernel variants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import asic_ops as A
from compile.kernels import ref as R

finite = dict(allow_nan=False, allow_infinity=False)


# --- exp: range-reduced Taylor-6 --------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.floats(min_value=-80.0, max_value=10.0, **finite))
def test_exp_rel_error(x):
    got = float(A.exp_taylor6(jnp.float32(x)))
    want = float(np.exp(np.float32(x)))
    assert got == np.float32(got)  # finite
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_exp_softmax_range_vector():
    xs = jnp.linspace(-30.0, 0.0, 601)
    rel = jnp.abs(A.exp_taylor6(xs) - jnp.exp(xs)) / jnp.exp(xs)
    assert float(jnp.max(rel)) < 1e-5


def test_exp_saturates_not_nan():
    xs = jnp.array([-1e4, -200.0, 100.0, 1e4], jnp.float32)
    out = np.asarray(A.exp_taylor6(xs))
    assert np.all(np.isfinite(out))


# --- reciprocal: Newton-Raphson division (Algorithm 1) -----------------------

@settings(max_examples=50, deadline=None)
@given(st.floats(min_value=1e-20, max_value=1e20, **finite),
       st.booleans())
def test_reciprocal_rel_error(x, neg):
    if neg:
        x = -x
    got = float(A.reciprocal_nr(jnp.float32(x)))
    np.testing.assert_allclose(got, 1.0 / np.float32(x), rtol=1e-5)


def test_reciprocal_three_iterations_suffice():
    """Paper: for 16-bit precision three iterations give an accurate
    result; for f32, three iterations are also enough (quadratic conv.)."""
    d = jnp.array([0.37, 1.0, 2.0, 9.87e6, 3.3e-7], jnp.float32)
    rel = jnp.abs(A.reciprocal_nr(d, iters=3) * d - 1.0)
    assert float(jnp.max(rel)) < 2e-6


def test_reciprocal_bf16_two_iterations():
    """bf16 (8 mantissa bits) converges even faster — 2 iterations."""
    d = jnp.array([0.37, 1.0, 2.0, 100.0], jnp.float32)
    rel = jnp.abs(A.reciprocal_nr(d, iters=2) * d - 1.0)
    assert float(jnp.max(rel)) < 1e-4  # well inside bf16 epsilon (~0.0078)


# --- rsqrt: Quake fast inverse square root (Algorithm 2) ---------------------

@settings(max_examples=50, deadline=None)
@given(st.floats(min_value=1e-30, max_value=1e30, **finite))
def test_rsqrt_rel_error(x):
    got = float(A.rsqrt_fast(jnp.float32(x)))
    want = 1.0 / np.sqrt(np.float32(x))
    np.testing.assert_allclose(got, want, rtol=5e-5)


def test_rsqrt_single_iteration_bf16():
    """Paper: 'it can converge in a single step iteration' at bf16; the
    design takes a conservative two."""
    d = jnp.array([0.5, 1.0, 2.0, 42.0], jnp.float32)
    rel = jnp.abs(A.rsqrt_fast(d, iters=1) * jnp.sqrt(d) - 1.0)
    assert float(jnp.max(rel)) < 5e-3  # within bf16 epsilon


# --- tanh / GELU -------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.floats(min_value=-50.0, max_value=50.0, **finite))
def test_tanh_abs_error(x):
    got = float(A.tanh_exp(jnp.float32(x)))
    np.testing.assert_allclose(got, np.tanh(np.float32(x)), atol=2e-6)


@settings(max_examples=50, deadline=None)
@given(st.floats(min_value=-30.0, max_value=30.0, **finite))
def test_gelu_abs_error(x):
    got = float(A.gelu_asic(jnp.float32(x)))
    want = float(R.gelu_ref(jnp.float32(x)))
    np.testing.assert_allclose(got, want, atol=1e-5 * max(1.0, abs(want)))


# --- softmax / layernorm -----------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 300), scale=st.floats(0.1, 20.0, **finite),
       seed=st.integers(0, 2**31 - 1))
def test_softmax_matches_ref(n, scale, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale
    got = np.asarray(A.softmax_asic(x))
    want = np.asarray(R.softmax_ref(x))
    np.testing.assert_allclose(got, want, atol=1e-5)
    np.testing.assert_allclose(got.sum(), 1.0, atol=1e-4)


def test_softmax_masked():
    x = jnp.arange(16, dtype=jnp.float32)
    mask = jnp.arange(16) <= 7
    got = np.asarray(A.softmax_asic(x, mask))
    assert np.all(got[8:] == 0.0)
    np.testing.assert_allclose(got.sum(), 1.0, atol=1e-4)
    want = np.asarray(R.softmax_ref(x, mask))
    np.testing.assert_allclose(got, want, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 512), seed=st.integers(0, 2**31 - 1))
def test_layernorm_matches_ref(n, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (n,)) * 3 + 0.5
    g = jax.random.normal(k2, (n,)) * 0.2 + 1.0
    b = jax.random.normal(k3, (n,)) * 0.1
    np.testing.assert_allclose(np.asarray(A.layernorm_asic(x, g, b)),
                               np.asarray(R.layernorm_ref(x, g, b)),
                               atol=5e-4)


# --- Pallas-wrapped kernels --------------------------------------------------

def test_softmax_kernel_pallas():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 2
    np.testing.assert_allclose(np.asarray(A.softmax_kernel(x)),
                               np.asarray(R.softmax_ref(x)), atol=1e-5)


def test_layernorm_kernel_pallas():
    x = jax.random.normal(jax.random.PRNGKey(1), (128,))
    g, b = jnp.ones(128), jnp.zeros(128)
    np.testing.assert_allclose(np.asarray(A.layernorm_kernel(x, g, b)),
                               np.asarray(R.layernorm_ref(x, g, b)),
                               atol=5e-4)


def test_gelu_kernel_pallas():
    x = jnp.linspace(-4, 4, 64)
    np.testing.assert_allclose(np.asarray(A.gelu_kernel(x)),
                               np.asarray(R.gelu_ref(x)), atol=2e-6)


# --- golden values shared with rust arith ------------------------------------

def test_golden_values_rust_mirror():
    """These exact tuples are replicated in rust `arith::tests`; if this
    table changes, change both sides."""
    golden_recip = {1.0: 1.0, 2.0: 0.5, 0.25: 4.0, 3.0: 0.3333333}
    for d, want in golden_recip.items():
        np.testing.assert_allclose(float(A.reciprocal_nr(jnp.float32(d))),
                                   want, rtol=1e-5)
    golden_rsqrt = {1.0: 1.0, 4.0: 0.5, 0.25: 2.0, 2.0: 0.70710678}
    for d, want in golden_rsqrt.items():
        np.testing.assert_allclose(float(A.rsqrt_fast(jnp.float32(d))),
                                   want, rtol=5e-5)
    np.testing.assert_allclose(float(A.exp_taylor6(jnp.float32(-1.0))),
                               0.36787944, rtol=1e-5)
    np.testing.assert_allclose(float(A.tanh_exp(jnp.float32(0.5))),
                               0.46211716, rtol=1e-4)
