"""L2 decode step: Pallas/approx path vs the exact-math oracle, KV-cache
state threading, masking, and autoregressive generation invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import FUNC_CONFIGS, PAPER_CONFIGS
from compile import model as M

CFG = FUNC_CONFIGS["gpt-nano"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def step(params):
    import functools
    return jax.jit(functools.partial(M.decode_step, CFG))


def _tok(t):
    return jnp.array([t], jnp.int32)


def test_decode_matches_reference(params):
    kc, vc = M.empty_caches(CFG)
    lg, kc1, vc1 = M.decode_step(CFG, params, _tok(5), _tok(0), kc, vc)
    lr, kr1, vr1 = M.reference_decode_step(CFG, params, _tok(5), _tok(0), kc, vc)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lr),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(kc1), np.asarray(kr1), atol=1e-4)
    np.testing.assert_allclose(np.asarray(vc1), np.asarray(vr1), atol=1e-4)


def test_decode_matches_reference_multi_step(params, step):
    kc, vc = M.empty_caches(CFG)
    kcr, vcr = kc, vc
    for i, t in enumerate([1, 2, 3, 4]):
        lg, kc, vc = step(params, _tok(t), _tok(i), kc, vc)
        lr, kcr, vcr = M.reference_decode_step(CFG, params, _tok(t), _tok(i),
                                               kcr, vcr)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lr),
                               rtol=1e-3, atol=1e-4)


def test_cache_written_only_at_pos(params, step):
    kc, vc = M.empty_caches(CFG)
    _, kc1, vc1 = step(params, _tok(7), _tok(3), kc, vc)
    k = np.asarray(kc1)
    # row 3 written for every layer, all other rows untouched (zero)
    assert np.all(k[:, 3, :] != 0.0)
    mask = np.ones(CFG.max_seq, bool)
    mask[3] = False
    assert np.all(k[:, mask, :] == 0.0)


def test_future_cache_rows_do_not_affect_logits(params, step):
    """Causal masking: garbage beyond `pos` must be invisible."""
    kc, vc = M.empty_caches(CFG)
    lg0, kc1, vc1 = step(params, _tok(3), _tok(0), kc, vc)
    poisoned_k = kc.at[:, 5:, :].set(1e3)
    poisoned_v = vc.at[:, 5:, :].set(-1e3)
    lg1, _, _ = step(params, _tok(3), _tok(0), poisoned_k, poisoned_v)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1),
                               rtol=1e-5, atol=1e-5)


def test_past_cache_rows_do_affect_logits(params, step):
    kc, vc = M.empty_caches(CFG)
    _, kc1, vc1 = step(params, _tok(3), _tok(0), kc, vc)
    lg_a, _, _ = step(params, _tok(4), _tok(1), kc1, vc1)
    lg_b, _, _ = step(params, _tok(4), _tok(1), kc, vc)  # history erased
    assert float(np.max(np.abs(np.asarray(lg_a) - np.asarray(lg_b)))) > 1e-4


def test_generate_deterministic(params):
    a = M.generate(CFG, params, [1, 2, 3], 6)
    b = M.generate(CFG, params, [1, 2, 3], 6)
    assert a == b
    assert len(a) == 9
    assert all(0 <= t < CFG.vocab for t in a)


def test_generate_prefix_consistency(params):
    """Greedy decoding is prefix-stable: generating 3 then 3 more equals
    generating 6."""
    a = M.generate(CFG, params, [1, 2, 3], 6)
    b = M.generate(CFG, params, a[:6], 3)
    assert b == a


def test_flat_decode_fn_signature(params):
    flat = [params[n] for n in M.PARAM_NAMES]
    kc, vc = M.empty_caches(CFG)
    fn = M.flat_decode_fn(CFG)
    lg, _, _ = fn(_tok(1), _tok(0), kc, vc, *flat)
    lg2, _, _ = M.decode_step(CFG, params, _tok(1), _tok(0), kc, vc)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg2))


def test_param_shapes_cover_param_names():
    shapes = M.param_shapes(CFG)
    assert set(shapes) == set(M.PARAM_NAMES)
    p = M.init_params(CFG)
    for n in M.PARAM_NAMES:
        assert tuple(p[n].shape) == tuple(shapes[n])


# --- Fig. 1 cross-check: parameter / op counts -------------------------------

def test_paper_param_counts():
    """Fig. 1a: parameter counts of the paper models (±2% of published)."""
    published = {
        "gpt2-small": 124e6, "gpt2-medium": 355e6,
        "gpt2-large": 774e6, "gpt2-xl": 1558e6,
        "gpt3-small": 125e6, "gpt3-medium": 350e6,
        "gpt3-large": 760e6, "gpt3-xl": 1320e6,
    }
    for name, want in published.items():
        got = PAPER_CONFIGS[name].n_params()
        assert abs(got - want) / want < 0.06, (name, got, want)


def test_ops_per_parameter_ratio_low():
    """Fig. 1b: GPT ops/parameter ~ 2 (vs ~48 for ResNet-18) — the
    memory-bound motivation for PIM."""
    for cfg in PAPER_CONFIGS.values():
        ratio = cfg.flops_per_token(1024) / cfg.n_params()
        assert 1.5 < ratio < 3.0, (cfg.name, ratio)
